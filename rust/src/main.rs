//! `uniap` — the UniAP coordinator CLI.
//!
//! Commands:
//! * `plan` — run the UOP planner (or a baseline) for a model × environment
//!   × mini-batch, print the plan, the estimate and the simulated outcome
//!   (or the machine-readable `PlanResponse` with `--json`).
//! * `sweep` — print the full UOP candidate log (Figure 4b style).
//! * `serve` — drain a JSON file of `PlanRequest`s concurrently through
//!   one `PlannerService` (shared caches, per-request deadlines) and print
//!   the `PlanResponse` array.
//! * `profile` — show the analytic profile of an environment for a model.
//! * `train` — execute a real GPipe training run over the AOT artifacts
//!   (see `examples/train_pipeline.rs` for the scripted version).
//! * `calibrate` — measure local PJRT matmul throughput.
//!
//! `plan` and `sweep` are thin front ends over [`PlannerService`] — the
//! CLI builds a `PlanRequest` from the flags and prints the response.

use uniap::baselines::BaselineKind;
use uniap::cli::Args;
use uniap::cluster::ClusterEnv;
use uniap::cost::Schedule;
use uniap::planner::Engine;
use uniap::profiling::Profile;
use uniap::service::{
    resolve_model, resolve_workload, PlanRequest, PlanResponse, PlannerService, Status,
};
use uniap::sim::{simulate_plan, SimConfig};
use uniap::util::json::Json;

const USAGE: &str = "\
uniap — UniAP automatic-parallelism planner (paper reproduction)

USAGE: uniap <command> [options]

COMMANDS:
  plan       --model <bert|t5|t5-16|vit|swin|llama-7b|llama-13b
                      |unet|unet-small|diamond>
             --env <EnvA|EnvB|EnvC|EnvD|EnvD-{n}n|EnvE|EnvF> --batch <B>
             (unet/diamond are operator DAGs, linearized into virtual
             layers before planning; request files may also inline a
             \"dag\" object — see examples/requests_dag.json)
             (EnvF is the heterogeneous zoo env — one V100 node + one
             TITAN node; [--cluster <file.json>] plans against an inline
             cluster description instead of a preset, and request files
             may inline the same object under \"cluster\" — see
             examples/requests_cluster.json)
             [--method <uniap|galvatron|alpa|inter|intra|megatron|deepspeed>]
             [--engine <auto|chain|miqp>] [--schedule <gpipe|1f1b>]
             [--deadline SECS] [--max-pp N] [--threads N] [--json] [--quiet]
  sweep      same selectors as plan; prints every (pp_size, c) candidate
             [--json]
  serve      --requests <file.json> [--concurrency N] [--pretty] [--validate]
             drains the request file through one shared PlannerService
             --listen <host:port> [--state-dir DIR] [--snapshot-secs N]
             [--max-frame-bytes N] [--sync-from <host:port>]
             [--max-connections N] [--max-inflight N] [--resync-secs N]
             [--peers <addr,addr,...>] [--advertise <host:port>]
             [--max-sync-bytes N]
             long-running socket mode: one JSON request (or array) per
             line in, one response line out; ctrl-c shuts down gracefully
             and, with --state-dir, persists the planner caches for the
             next start. Several servers may share one --state-dir (each
             writes its own generation file and they merge). --sync-from
             additionally pulls a peer server's snapshot at startup and
             merges it, warming this server from another machine; a peer
             that is down at boot degrades to a background re-sync every
             --resync-secs. --peers lists every fleet member (including
             this node, identified by --advertise or the --listen addr):
             each workload key gets a consistent-hash owner, misses are
             warm-forwarded to it, and the background tick becomes gossip
             anti-entropy across the ring. Load beyond
             --max-connections/--max-inflight is shed with a typed
             \"busy\" response; {\"op\":\"health\"} and {\"op\":\"stats\"}
             probes are answered even while shedding
             --connect <host:port> --requests <file.json> [--pretty]
             client mode: send the request file to a listening server
             --sync-from <host:port> --state-dir DIR [--max-sync-bytes N]
             one-shot sync: pull the peer's snapshot, merge it into the
             state dir, and exit
  profile    --model <name> --env <name>
  train      --artifacts <dir> --steps N [--micro N] [--lr F]
  calibrate  [--size N] [--iters N]
  version
";

/// Build a `PlanRequest` from the shared `plan`/`sweep` selector flags.
fn plan_request(args: &Args) -> Result<PlanRequest, String> {
    // Removed options fail loudly instead of being silently ignored.
    if args.has("time-limit") {
        return Err(
            "--time-limit was replaced by --deadline SECS: one wall-clock budget for the \
             whole request, threaded into every solve (DESIGN.md §Cancellation)"
                .to_string(),
        );
    }
    if args.has("mem-buckets") {
        return Err(
            "--mem-buckets only tuned the legacy dense chain engine, which the planner \
             service never uses (the production engine tracks memory exactly)"
                .to_string(),
        );
    }
    let batch = args.get_usize("batch", 16)?;
    let mut req =
        PlanRequest::new(&args.get("id", ""), &args.get("model", "bert"), &args.get("env", "EnvA"), batch);
    let method = args.get("method", "uniap");
    req.method = BaselineKind::by_key(&method).ok_or(format!("unknown method {method}"))?;
    let engine = args.get("engine", "auto");
    req.engine = Engine::by_key(&engine).ok_or(format!("unknown engine {engine}"))?;
    let schedule = args.get("schedule", "gpipe");
    req.schedule = Schedule::by_key(&schedule).ok_or(format!("unknown schedule {schedule}"))?;
    let deadline = args.get_f64("deadline", 0.0)?;
    if deadline > 0.0 {
        req.deadline_secs = Some(deadline);
    }
    let max_pp = args.get_usize("max-pp", 0)?;
    if max_pp > 0 {
        req.max_pp = Some(max_pp);
    }
    let threads = args.get_usize("threads", 0)?;
    if threads > 0 {
        req.threads = Some(threads);
    }
    let cluster_path = args.get("cluster", "");
    if !cluster_path.is_empty() {
        let text = std::fs::read_to_string(&cluster_path)
            .map_err(|e| format!("--cluster {cluster_path}: {e}"))?;
        let j = Json::parse(&text).map_err(|e| format!("--cluster {cluster_path}: {e}"))?;
        req.cluster =
            Some(ClusterEnv::from_json(&j).map_err(|e| format!("--cluster {cluster_path}: {e}"))?);
    }
    Ok(req)
}

/// Surface an `error`-status response as a CLI error.
fn ok_or_cli_error(resp: &PlanResponse) -> Result<(), String> {
    if resp.status == Status::Error {
        Err(resp.error.clone().unwrap_or_else(|| "request failed".to_string()))
    } else {
        Ok(())
    }
}

fn cmd_plan(args: &Args) -> Result<(), String> {
    let req = plan_request(args)?;
    let service = PlannerService::new();
    let resp = service.plan(&req);
    if args.flag("json") {
        ok_or_cli_error(&resp)?;
        println!("{}", resp.to_json().to_pretty());
        return Ok(());
    }
    ok_or_cli_error(&resp)?;
    let env = uniap::service::resolve_env(&req)?;
    let workload = resolve_workload(&req)?;
    let graph = workload.graph;
    println!("# {} · {} · B={} · {}", req.method.label(), graph.name, req.batch, env.name);
    if let Some(report) = &workload.linearization {
        // DAG front-end: say what the planner actually solved — the
        // virtual layers named in the per-stage lines below
        println!("{}", report.summary());
    }
    println!("strategy optimization time: {}", uniap::util::fmt_secs(resp.timings.solve_secs));
    match &resp.plan {
        None => {
            let why = resp.error.as_deref().unwrap_or("SOL×");
            println!("result: {} ({})", why, resp.status.key());
        }
        Some(plan) => {
            println!("plan: {}", plan.summary());
            if !args.flag("quiet") {
                for (i, range) in plan.stage_ranges().iter().enumerate() {
                    let Some((a, b)) = range else { continue };
                    let labels: Vec<String> = (*a..=*b)
                        .map(|u| format!("{}:{}", graph.layers[u].name, plan.strategy_of(u).label()))
                        .collect();
                    println!("  stage {i}: {}", labels.join(" "));
                }
            }
            // cached by the plan() call for chain workloads; rebuilt (a
            // pure function of env + lowered graph) for DAG ones, whose
            // cache entries live under the dag: fingerprint domain
            let profile = service.profile(&env, &graph);
            let sim = simulate_plan(&graph, &profile, plan, &SimConfig::default());
            println!(
                "simulated: {:.2} ± {:.2} samples/s (tpi {:.4}s, MFU {:.1}%, bubble {:.1}%{})",
                sim.throughput,
                sim.throughput_std,
                sim.tpi,
                100.0 * sim.mfu,
                100.0 * sim.bubble_frac,
                if sim.oom { ", CUDA× OOM" } else { "" },
            );
            let ree = uniap::metrics::ree(sim.throughput, plan.est_throughput());
            println!("estimate: {:.2} samples/s (REE {:.2}%)", plan.est_throughput(), 100.0 * ree);
        }
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<(), String> {
    let req = plan_request(args)?;
    let service = PlannerService::new();
    let resp = service.plan(&req);
    ok_or_cli_error(&resp)?;
    if args.flag("json") {
        println!("{}", resp.to_json().to_pretty());
        return Ok(());
    }
    let mut table = uniap::report::Table::new(&["pp_size", "c", "est TPI (s)", "solve (s)"]);
    for l in &resp.log {
        table.row(vec![
            l.pp_size.to_string(),
            l.num_micro.to_string(),
            l.tpi.map(|t| format!("{t:.4}")).unwrap_or_else(|| "SOL×".to_string()),
            format!("{:.3}", l.solve_secs),
        ]);
    }
    print!("{}", table.to_markdown());
    println!("total: {}", uniap::util::fmt_secs(resp.timings.solve_secs));
    if let Some(best) = &resp.plan {
        println!("best: {}", best.summary());
    }
    Ok(())
}

/// Re-parse the emitted response text and check every plan against the
/// paper's constraints — the smoke gate `serve --validate` runs in CI.
/// Profiles come from the serving service's cache (already warm).
fn validate_responses(
    text: &str,
    reqs: &[PlanRequest],
    service: &PlannerService,
) -> Result<usize, String> {
    let arr = Json::parse(text)?;
    let items = arr.as_arr().ok_or("response text is not a JSON array")?;
    if items.len() != reqs.len() {
        return Err(format!("{} responses for {} requests", items.len(), reqs.len()));
    }
    let mut plans = 0usize;
    for (i, item) in items.iter().enumerate() {
        let resp = PlanResponse::from_json(item).map_err(|e| format!("response [{i}]: {e}"))?;
        if resp.status == Status::Error {
            return Err(format!(
                "response [{i}] errored: {}",
                resp.error.as_deref().unwrap_or("unknown")
            ));
        }
        let Some(plan) = &resp.plan else { continue };
        let req = &reqs[i];
        let env = uniap::service::resolve_env(req)?;
        // DAG workloads validate against the *lowered* chain — the graph
        // the plan was actually solved over
        let graph = resolve_workload(req)?.graph;
        let profile = service.profile(&env, &graph);
        let costs = uniap::cost::cost_modeling_sched(
            &profile,
            &graph,
            plan.pp_size,
            plan.batch,
            plan.num_micro,
            req.schedule,
        );
        let violations = plan.check(&graph, &costs);
        if !violations.is_empty() {
            return Err(format!("response [{i}] plan violates constraints: {violations:?}"));
        }
        plans += 1;
    }
    Ok(plans)
}

/// Long-running socket mode: `uniap serve --listen <addr>`.
fn cmd_serve_listen(args: &Args) -> Result<(), String> {
    let addr = args.require("listen").map_err(|_| {
        "--listen needs an address (host:port, e.g. 127.0.0.1:7741; port 0 picks one)".to_string()
    })?;
    let peers = match args.opt("peers") {
        None if args.has("peers") => {
            return Err("--peers needs a comma-separated address list (host:port,host:port,...)"
                .to_string())
        }
        None => Vec::new(),
        Some(raw) => uniap::service::parse_peer_list(raw)?,
    };
    let opts = uniap::service::ServerOptions {
        state_dir: {
            let dir = args.get("state-dir", "");
            (!dir.is_empty()).then(|| std::path::PathBuf::from(dir))
        },
        snapshot_secs: args.get_secs("snapshot-secs", 30.0)?,
        max_frame_bytes: args
            .get_usize("max-frame-bytes", uniap::util::net::DEFAULT_MAX_FRAME_BYTES)?,
        watch_sigint: true,
        max_connections: args
            .get_usize("max-connections", uniap::service::server::DEFAULT_MAX_CONNECTIONS)?,
        max_inflight: args
            .get_usize("max-inflight", uniap::service::server::DEFAULT_MAX_INFLIGHT)?,
        sync_from: args.opt("sync-from").map(str::to_string),
        resync_secs: args.get_secs("resync-secs", 300.0)?,
        peers,
        advertise: args.opt("advertise").map(str::to_string),
        max_sync_bytes: args
            .get_usize("max-sync-bytes", uniap::service::server::DEFAULT_MAX_SYNC_BYTES)?,
    };
    let service = PlannerService::new();
    if let Some(dir) = &opts.state_dir {
        match service.load_state(dir) {
            uniap::service::LoadOutcome::Loaded { frontiers, bases } => {
                eprintln!("restored state: {frontiers} frontiers, {bases} cost bases");
            }
            uniap::service::LoadOutcome::ColdStart { reason: None } => {
                eprintln!("no snapshot in {} — cold start", dir.display());
            }
            uniap::service::LoadOutcome::ColdStart { reason: Some(why) } => {
                eprintln!("snapshot in {} unusable ({why}) — cold start", dir.display());
            }
        }
    }
    if let Some(peer) = args.opt("sync-from") {
        // warm from a peer machine before accepting traffic; a dead or
        // confused peer costs warmth, never availability (ISSUE 6): a
        // cheap health probe decides whether the full pull is worth
        // retrying at boot at all, transient failures back off and
        // retry within the sync budget, and a peer that stays down
        // degrades to the server's background re-sync tick
        match uniap::service::server::probe_health(peer, std::time::Duration::from_secs(2)) {
            Ok(()) => {
                let mut retries = 0usize;
                let sync = uniap::service::server::fetch_snapshot_retrying(
                    peer,
                    opts.max_sync_bytes,
                    uniap::service::server::DEFAULT_SYNC_TIMEOUT,
                    &mut |attempt, err| {
                        retries += 1;
                        eprintln!("sync from {peer} attempt {attempt} failed ({err}) — retrying");
                    },
                );
                service.note_sync_retries(retries);
                match sync {
                    Ok(snap) => {
                        let (frontiers, bases) = service.merge_snapshot(&snap);
                        eprintln!(
                            "synced from {peer}: merged {frontiers} new frontiers, \
                             {bases} new cost bases"
                        );
                    }
                    Err(e) => eprintln!(
                        "sync from {peer} failed ({e}) — starting with local state \
                         and re-syncing in the background"
                    ),
                }
            }
            Err(e) => {
                service.note_sync_retries(1);
                eprintln!(
                    "peer {peer} is not answering ({e}) — starting with local state \
                     and re-syncing in the background"
                );
            }
        }
    }
    let server = uniap::service::Server::bind(&addr)?;
    if !uniap::service::server::install_sigint_handler() {
        eprintln!("note: no SIGINT hook on this platform; stop with a TCP-level kill");
    }
    eprintln!(
        "listening on {} (one JSON request or array per line; ctrl-c for graceful shutdown)",
        server.local_addr()
    );
    let shutdown = uniap::service::CancelToken::new();
    server.run(&service, &opts, &shutdown)?;
    let stats = service.stats();
    eprintln!(
        "shut down after {} connections, {} requests ({} plan-cache hits, \
         {} persisted-frontier hits, {} snapshots written; \
         {} requests shed, {} accept errors, {} sync retries, {} faults injected; \
         {} forwards, {} forward fallbacks, {} gossip rounds, {} gossip-merged entries)",
        stats.connections,
        stats.requests,
        stats.plan_hits,
        stats.persisted_frontier_hits,
        stats.snapshots_written,
        stats.requests_shed,
        stats.accept_errors,
        stats.sync_retries,
        stats.faults_injected,
        stats.forwards,
        stats.forward_fallbacks,
        stats.gossip_rounds,
        stats.gossip_merged_entries,
    );
    Ok(())
}

/// Client mode: `uniap serve --connect <addr> --requests <file>`.
fn cmd_serve_connect(args: &Args) -> Result<(), String> {
    use std::io::{BufReader, BufWriter};
    let addr = args.require("connect")?;
    let path = args.require("requests")?;
    let text = std::fs::read_to_string(&path).map_err(|e| format!("cannot read {path}: {e}"))?;
    // parse + re-emit compactly: validates locally and guarantees the
    // frame is a single line whatever the file's formatting
    let reqs = PlanRequest::parse_batch(&text)?;
    let frame =
        Json::Arr(reqs.iter().map(PlanRequest::to_json).collect()).to_string();
    let stream = std::net::TcpStream::connect(&addr)
        .map_err(|e| format!("cannot connect to {addr:?}: {e}"))?;
    let read_half = stream.try_clone().map_err(|e| format!("cannot clone stream: {e}"))?;
    let mut writer = BufWriter::new(stream);
    uniap::util::net::write_frame(&mut writer, &frame)?;
    let mut reader = BufReader::new(read_half);
    let never = || false;
    // the reply direction is trusted (our own server) and a fully-solved
    // batch must never be discarded client-side over a size cap — allow
    // up to 1 GiB, far beyond any real response array
    let reply = uniap::util::net::read_frame(&mut reader, 1 << 30, &never)
        .map_err(|e| format!("no response: {e}"))?
        .ok_or("server closed the connection without responding")?;
    let parsed = Json::parse(&reply)?;
    println!("{}", if args.flag("pretty") { parsed.to_pretty() } else { parsed.to_string() });
    // frame-level failures (oversized frame, malformed batch, load shed)
    // come back as a single *object*, not an array — exit non-zero for
    // both; a "busy" shed is a failure for a one-shot client too (the
    // caller owns the retry policy, and a script must see the miss)
    let is_error = |r: &Json| {
        matches!(r.get("status").and_then(Json::as_str), Some("error") | Some("busy"))
    };
    let n_err = match parsed.as_arr() {
        Some(items) => items.iter().filter(|r| is_error(r)).count(),
        None => is_error(&parsed) as usize,
    };
    if n_err > 0 {
        return Err(format!("{n_err} response(s) came back with status \"error\" or \"busy\""));
    }
    Ok(())
}

/// One-shot state sync: `uniap serve --sync-from <addr> --state-dir DIR`
/// (no `--listen`). Pulls the peer's snapshot, merges it with whatever
/// the state dir already holds, and writes the union back — a warm
/// cache courier for fleets that stage state out-of-band.
fn cmd_serve_sync(args: &Args) -> Result<(), String> {
    let peer = args.require("sync-from")?;
    let dir = args.require("state-dir").map_err(|_| {
        "--sync-from without --listen needs --state-dir DIR to merge the pulled snapshot into"
            .to_string()
    })?;
    let dir = std::path::PathBuf::from(dir);
    let service = PlannerService::new();
    if let uniap::service::LoadOutcome::Loaded { frontiers, bases } = service.load_state(&dir) {
        eprintln!("local state: {frontiers} frontiers, {bases} cost bases");
    }
    let cap =
        args.get_usize("max-sync-bytes", uniap::service::server::DEFAULT_MAX_SYNC_BYTES)?;
    let snap = uniap::service::server::fetch_snapshot_retrying(
        &peer,
        cap,
        uniap::service::server::DEFAULT_SYNC_TIMEOUT,
        &mut |attempt, err| {
            eprintln!("sync from {peer} attempt {attempt} failed ({err}) — retrying")
        },
    )?;
    let (frontiers, bases) = service.merge_snapshot(&snap);
    let path = service.save_state(&dir)?;
    eprintln!(
        "synced from {peer}: merged {frontiers} new frontiers, {bases} new cost bases into {}",
        path.display()
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<(), String> {
    if args.has("listen") {
        return cmd_serve_listen(args);
    }
    if args.has("connect") {
        return cmd_serve_connect(args);
    }
    if args.has("sync-from") {
        return cmd_serve_sync(args);
    }
    let path = args.require("requests")?;
    let text = std::fs::read_to_string(&path).map_err(|e| format!("cannot read {path}: {e}"))?;
    let reqs = PlanRequest::parse_batch(&text)?;
    let service = PlannerService::new();
    let default_conc = reqs.len().clamp(1, 4);
    let concurrency = args.get_usize("concurrency", default_conc)?;
    let responses = service.serve(&reqs, concurrency);
    let arr = Json::Arr(responses.iter().map(PlanResponse::to_json).collect());
    let out = if args.flag("pretty") { arr.to_pretty() } else { arr.to_string() };
    println!("{out}");
    let stats = service.stats();
    eprintln!(
        "served {} requests (concurrency {concurrency}, {} sweep threads each): \
         profile cache {}/{} hit, cost-base cache {}/{} hit",
        reqs.len(),
        service.threads_per_request(concurrency.min(reqs.len().max(1))),
        stats.profile_hits,
        stats.profile_hits + stats.profile_misses,
        stats.base_hits,
        stats.base_hits + stats.base_misses,
    );
    if args.flag("validate") {
        let plans = validate_responses(&out, &reqs, &service)?;
        eprintln!("validated: all responses parse, {plans} plans pass Plan::check");
    }
    Ok(())
}

fn cmd_profile(args: &Args) -> Result<(), String> {
    let env_name = args.get("env", "EnvA");
    let model_name = args.get("model", "bert");
    let env = ClusterEnv::by_name(&env_name).ok_or(format!("unknown env {env_name}"))?;
    let workload = resolve_model(&model_name)?;
    let graph = workload.graph;
    let profile = Profile::analytic(&env, &graph);
    println!("# profile of {} on {}", graph.name, env.name);
    if let Some(report) = &workload.linearization {
        println!("{}", report.summary());
    }
    if env.is_heterogeneous() {
        println!("devices: {} across {} nodes:", env.total_devices(), env.node_table.len());
        for (i, node) in env.node_table.iter().enumerate() {
            println!(
                "  node {i}: {} × {} ({} GiB)",
                node.gpus,
                node.device.name,
                node.device.mem_bytes / 1e9
            );
        }
    } else {
        println!(
            "devices: {} × {} ({} GiB)",
            env.total_devices(),
            env.device.name,
            env.device.mem_bytes / 1e9
        );
    }
    let mut seen = std::collections::BTreeSet::new();
    let mut table = uniap::report::Table::new(&["layer type", "tp=1 (ms/sample)", "tp=2", "tp=4"]);
    for l in &graph.layers {
        if seen.insert(l.type_key.clone()) {
            table.row(vec![
                l.type_key.clone(),
                format!("{:.3}", 1e3 * profile.fwd_time_per_sample(&l.type_key, 1)),
                format!("{:.3}", 1e3 * profile.fwd_time_per_sample(&l.type_key, 2)),
                format!("{:.3}", 1e3 * profile.fwd_time_per_sample(&l.type_key, 4)),
            ]);
        }
    }
    print!("{}", table.to_markdown());
    println!("CCOC: {:.2}, memory limit: {}", profile.ccoc, uniap::util::gib(profile.mem_limit()));
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_train(_args: &Args) -> Result<(), String> {
    Err("the `train` command needs the `pjrt` feature (PJRT runtime / xla crate)".to_string())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_calibrate(_args: &Args) -> Result<(), String> {
    Err("the `calibrate` command needs the `pjrt` feature (PJRT runtime / xla crate)".to_string())
}

#[cfg(feature = "pjrt")]
fn cmd_train(args: &Args) -> Result<(), String> {
    let dir = args.get("artifacts", "artifacts");
    let steps = args.get_usize("steps", 50)?;
    let micro = args.get_usize("micro", 4)?;
    let lr = args.get_f64("lr", 3e-3)? as f32;
    let mut exec = uniap::exec::pipeline::PipelineExecutor::load(&dir, lr)
        .map_err(|e| format!("{e:#}"))?;
    let m = exec.meta.clone();
    println!(
        "# training gpt(d={}, layers={}, vocab={}) — {} stages, micro-batch {}, {} micro-batches/step",
        m.d_model, m.layers, m.vocab, m.stages, m.micro_batch, micro
    );
    let mut corpus = uniap::exec::data::Corpus::new(m.vocab, 42);
    for step in 0..steps {
        let (toks, tgts) = corpus.next_batch(m.micro_batch * micro, m.seq);
        let stats = exec.train_step(&toks, &tgts, micro).map_err(|e| format!("{e:#}"))?;
        if step % 10 == 0 || step + 1 == steps {
            println!("step {step:>4}  loss {:.4}  ({:.2}s)", stats.loss, stats.step_secs);
        }
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_calibrate(args: &Args) -> Result<(), String> {
    let size = args.get_usize("size", 512)?;
    let iters = args.get_usize("iters", 8)?;
    let c = uniap::profiling::measured::calibrate_matmul(size, iters).map_err(|e| format!("{e:#}"))?;
    println!("achieved f32 matmul: {:.2} GFLOP/s ({} over {} iters)", c.achieved_f32 / 1e9, uniap::util::fmt_secs(c.bench_secs), iters);
    Ok(())
}

fn main() {
    let tokens: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(&tokens) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    let result = match args.command.as_str() {
        "plan" => cmd_plan(&args),
        "sweep" => cmd_sweep(&args),
        "serve" => cmd_serve(&args),
        "profile" => cmd_profile(&args),
        "train" => cmd_train(&args),
        "calibrate" => cmd_calibrate(&args),
        "version" => {
            println!("uniap {}", uniap::VERSION);
            Ok(())
        }
        "" | "help" => {
            print!("{USAGE}");
            Ok(())
        }
        other => Err(format!("unknown command {other}")),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
