//! The lint engine, turned on itself.
//!
//! Three layers of assurance:
//!
//! 1. **The tree is clean** — `lint_tree` over the real `rust/src/` with
//!    the repo's `lint.allow` must report zero diagnostics. This is the
//!    same check CI runs via the `uniap_lint` binary; having it inside
//!    `cargo test` means tier-1 alone catches regressions.
//! 2. **The fixtures fire** — each deliberately-violating fixture under
//!    `rust/src/analysis/fixtures/` produces exactly the expected
//!    diagnostics at the expected positions, and each clean twin produces
//!    none. Fixtures are linted under synthetic paths because rule scope
//!    is path-driven.
//! 3. **The allowlist is honest** — the repo `lint.allow` parses,
//!    round-trips, and carries no stale entries: every entry must
//!    suppress at least one diagnostic of the unfiltered tree.

use std::path::PathBuf;

use uniap::analysis::{lint_source, lint_tree, Allowlist};

fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

fn repo_allowlist() -> Allowlist {
    let path = repo_root().join("lint.allow");
    let text = std::fs::read_to_string(&path).expect("repo lint.allow exists");
    match Allowlist::parse(&text) {
        Ok(a) => a,
        Err((line, msg)) => panic!("lint.allow:{line}: {msg}"),
    }
}

#[test]
fn tree_is_lint_clean_under_repo_allowlist() {
    let src = repo_root().join("rust/src");
    let report = lint_tree(&src, &repo_allowlist()).expect("tree walk succeeds");
    let rendered = report.render();
    assert!(report.diagnostics.is_empty(), "rust/src must lint clean:\n{rendered}");
    let n = report.files_checked;
    assert!(n > 40, "walk saw only {n} files — wrong root?");
    assert!(report.suppressed > 0, "lint.allow should suppress something");
}

#[test]
fn allowlist_round_trips_and_has_no_stale_entries() {
    let allow = repo_allowlist();
    let round = Allowlist::parse(&allow.serialize()).expect("serialized form re-parses");
    assert_eq!(allow, round, "parse of serialize is the identity on entries");

    // Unfiltered tree: every allowlist entry must still pay its way.
    let src = repo_root().join("rust/src");
    let raw = lint_tree(&src, &Allowlist::default()).expect("tree walk succeeds");
    for entry in &allow.entries {
        let single = Allowlist { entries: vec![entry.clone()] };
        let used = raw
            .diagnostics
            .iter()
            .any(|d| single.suppresses(d.rule.id(), &d.file, &d.snippet));
        let label = format!("{} {} {}", entry.rule, entry.path, entry.needle);
        assert!(used, "stale lint.allow entry (suppresses nothing): {label}");
    }
}

/// Assert `source` linted under `path` yields exactly `expected`
/// `(line, col, rule-id)` triples, in order.
fn expect_diags(path: &str, source: &str, expected: &[(usize, usize, &str)]) {
    let diags = lint_source(path, source);
    let got: Vec<(usize, usize, &str)> =
        diags.iter().map(|d| (d.line, d.col, d.rule.id())).collect();
    let rendered: Vec<String> = diags.iter().map(|d| d.render()).collect();
    let rendered = rendered.join("\n");
    assert_eq!(got, expected, "wrong diagnostics for {path}:\n{rendered}");
}

#[test]
fn fixture_float_determinism() {
    let bad = include_str!("../src/analysis/fixtures/float_bad.rs");
    expect_diags("cost/float_bad.rs", bad, &[(8, 15, "float-determinism")]);
    let ok = include_str!("../src/analysis/fixtures/float_ok.rs");
    expect_diags("metrics/float_ok.rs", ok, &[]);
}

#[test]
fn fixture_no_panic_serving() {
    let bad = include_str!("../src/analysis/fixtures/panic_bad.rs");
    let want = [(4, 31, "no-panic-serving"), (9, 11, "no-panic-serving")];
    expect_diags("service/panic_bad.rs", bad, &want);
    let ok = include_str!("../src/analysis/fixtures/panic_ok.rs");
    expect_diags("service/panic_ok.rs", ok, &[]);
    // Scope is path-driven: the same violating source is fine outside the
    // serving layer.
    expect_diags("metrics/panic_bad.rs", bad, &[]);
}

#[test]
fn fixture_atomics_hygiene() {
    let bad = include_str!("../src/analysis/fixtures/atomics_bad.rs");
    let want = [(7, 26, "atomics-hygiene"), (11, 18, "atomics-hygiene")];
    expect_diags("util/atomics_bad.rs", bad, &want);
    // The load-into-`if` site gets the sharper control-flow message.
    let diags = lint_source("util/atomics_bad.rs", bad);
    let msg = &diags[1].message;
    assert!(msg.contains("control flow"), "expected control-flow wording: {msg}");
    let ok = include_str!("../src/analysis/fixtures/atomics_ok.rs");
    expect_diags("util/atomics_ok.rs", ok, &[]);
}

#[test]
fn fixture_wall_clock() {
    let bad = include_str!("../src/analysis/fixtures/wallclock_bad.rs");
    expect_diags("planner/wallclock_bad.rs", bad, &[(6, 14, "wall-clock")]);
    let ok = include_str!("../src/analysis/fixtures/wallclock_ok.rs");
    expect_diags("planner/wallclock_ok.rs", ok, &[]);
}

#[test]
fn fixture_sentinel_ban() {
    let bad = include_str!("../src/analysis/fixtures/sentinel_bad.rs");
    let want = [(4, 5, "sentinel-ban"), (8, 5, "sentinel-ban")];
    expect_diags("planner/sentinel_bad.rs", bad, &want);
    let ok = include_str!("../src/analysis/fixtures/sentinel_ok.rs");
    expect_diags("planner/sentinel_ok.rs", ok, &[]);
}
