//! Reproduction-shape tests: the qualitative claims of the paper's tables
//! must hold on our testbed (the simulator) — who wins, what fails, and
//! roughly by how much. Absolute numbers differ (different substrate);
//! shapes must not.

use uniap::baselines::{megatron, Baseline, BaselineKind};
use uniap::cluster::ClusterEnv;
use uniap::cost::cost_modeling;
use uniap::graph::models;
use uniap::planner::{chain, chain_dense, PlannerConfig};
use uniap::profiling::Profile;
use uniap::sim::{simulate_plan, SimConfig};

fn sim_throughput(
    graph: &uniap::graph::Graph,
    profile: &Profile,
    plan: &uniap::planner::Plan,
) -> Option<f64> {
    let sim = simulate_plan(graph, profile, plan, &SimConfig::default());
    (!sim.oom).then_some(sim.throughput)
}

/// Regression pin for the Pareto-sparse interval-DP rewrite: on the paper
/// shapes the production engine must (a) stay feasible and constraint-
/// clean, and (b) never be worse than the frozen dense-grid reference —
/// the dense grid rounds memory *up*, so its feasible set is a subset and
/// exact-memory tracking can only help. Wherever the dense engine is
/// feasible the two optima must coincide to fp noise unless phantom
/// memory actually bit (in which case sparse is strictly better).
#[test]
fn sparse_engine_pins_paper_shape_plans_against_dense_reference() {
    let cfg = PlannerConfig::default();
    // (graph, env, B, pp, c, known_feasible) — BERT/EnvB/pp=2 feasibility
    // is pinned (it is the Appendix F workload); the other candidates are
    // consistency checks in whichever direction they resolve.
    let cases: Vec<(uniap::graph::Graph, ClusterEnv, usize, usize, usize, bool)> = vec![
        (models::bert_huge(), ClusterEnv::env_b(), 16, 2, 4, true),
        (models::bert_huge(), ClusterEnv::env_b(), 16, 4, 4, false),
        (models::vit_huge(), ClusterEnv::env_b(), 64, 2, 4, false),
        (models::llama_7b(), ClusterEnv::env_c(), 8, 2, 2, false),
    ];
    for (g, env, batch, pp, c, known_feasible) in cases {
        let profile = Profile::analytic(&env, &g);
        let costs = cost_modeling(&profile, &g, pp, batch, c);
        let sparse = chain::solve_chain(&g, &costs, &cfg);
        if known_feasible {
            assert!(sparse.is_some(), "{} pp={pp} c={c}: sparse SOL×", g.name);
        }
        if let Some(sparse) = &sparse {
            assert!(
                sparse.check(&g, &costs).is_empty(),
                "{}: {:?}",
                g.name,
                sparse.check(&g, &costs)
            );
        }
        if let Some(dense) = chain_dense::solve_chain_dense(&g, &costs, &cfg) {
            // the dense grid rounds memory up, so dense-feasible ⇒
            // sparse-feasible and the exact optimum can only be ≤
            let sparse = sparse.expect("dense feasible ⇒ sparse feasible");
            assert!(
                sparse.est_tpi <= dense.est_tpi * (1.0 + 1e-9),
                "{} pp={pp} c={c}: sparse {} worse than dense {}",
                g.name,
                sparse.est_tpi,
                dense.est_tpi
            );
        }
    }
}

/// Table 1, EnvB rows: UniAP ≥ Galvatron and ≥ Alpa in simulated
/// throughput on BERT-Huge (paper: 10.77 vs 6.27 vs 8.95).
#[test]
fn table1_envb_bert_uniap_wins() {
    let g = models::bert_huge();
    let profile = Profile::analytic(&ClusterEnv::env_b(), &g);
    let cfg = PlannerConfig::default();
    let uni = Baseline::run(BaselineKind::UniAP, &profile, &g, 16, &cfg);
    let gal = Baseline::run(BaselineKind::Galvatron, &profile, &g, 16, &cfg);
    let alp = Baseline::run(BaselineKind::Alpa, &profile, &g, 16, &cfg);
    let t_uni = sim_throughput(&g, &profile, &uni.plan.unwrap()).expect("uniap runs");
    let t_gal = sim_throughput(&g, &profile, &gal.plan.unwrap()).unwrap_or(0.0);
    let t_alp = sim_throughput(&g, &profile, &alp.plan.unwrap()).unwrap_or(0.0);
    assert!(t_uni >= t_gal * 0.999, "UniAP {t_uni} < Galvatron {t_gal}");
    assert!(t_uni >= t_alp * 0.999, "UniAP {t_uni} < Alpa {t_alp}");
}

/// Table 1, EnvC row: UniAP beats Galvatron clearly on Llama-7B (paper:
/// 3.80×) because Galvatron's greedy micro-batching/hierarchy picks a
/// shallower pipeline on the PCIe-only box.
#[test]
fn table1_envc_llama_uniap_speedup() {
    let g = models::llama_7b();
    let profile = Profile::analytic(&ClusterEnv::env_c(), &g);
    let cfg = PlannerConfig::default();
    let uni = Baseline::run(BaselineKind::UniAP, &profile, &g, 8, &cfg);
    let gal = Baseline::run(BaselineKind::Galvatron, &profile, &g, 8, &cfg);
    let t_uni = sim_throughput(&g, &profile, &uni.plan.expect("uniap plan")).expect("runs");
    let t_gal = gal
        .plan
        .and_then(|p| sim_throughput(&g, &profile, &p))
        .unwrap_or(f64::EPSILON);
    assert!(
        t_uni > 1.15 * t_gal,
        "expected a clear UniAP win on EnvC Llama: {t_uni} vs {t_gal}"
    );
}

/// Table 2 ablation shape on EnvB: restricting the space can only hurt;
/// intra-only is drastically slower for BERT (paper: 2.48 vs 10.77).
#[test]
fn table2_ablation_restrictions_hurt() {
    let g = models::bert_huge();
    let profile = Profile::analytic(&ClusterEnv::env_b(), &g);
    let cfg = PlannerConfig::default();
    let uni = Baseline::run(BaselineKind::UniAP, &profile, &g, 16, &cfg);
    let intra = Baseline::run(BaselineKind::IntraOnly, &profile, &g, 16, &cfg);
    let t_uni = sim_throughput(&g, &profile, &uni.plan.unwrap()).unwrap();
    let t_intra = intra
        .plan
        .and_then(|p| sim_throughput(&g, &profile, &p))
        .unwrap_or(0.0);
    assert!(
        t_uni > 1.5 * t_intra,
        "intra-only should be much slower on EnvB BERT: {t_uni} vs {t_intra}"
    );
}

/// Appendix F case-study shape: on EnvB the optimal BERT plan uses
/// pipelining so that the slow 10 Gbps inter-node link carries only P2P
/// traffic (never per-layer collectives), and TP never crosses a node.
/// (The paper's testbed lands on pp=2; our cluster model's exact optimum
/// is a deeper pipeline with the same topology alignment — see
/// EXPERIMENTS.md for the discussion.)
#[test]
fn appendix_f_bert_envb_topology_aligned_pipeline() {
    let g = models::bert_huge();
    let env = ClusterEnv::env_b();
    let profile = Profile::analytic(&env, &g);
    let res = uniap::planner::uop(&profile, &g, 16, &PlannerConfig::default());
    let plan = res.best.expect("feasible");
    assert!(plan.pp_size >= 2, "pipelining must be used: {}", plan.summary());
    // the inter-node boundary must coincide with a stage boundary: some
    // stage owns exactly the first node's GPUs up to rank 3.
    let per_stage = env.total_devices() / plan.pp_size;
    assert!(env.gpus_per_node % per_stage == 0 || per_stage % env.gpus_per_node == 0,
        "stages must tile nodes: pp={} on {}", plan.pp_size, plan.summary());
    // TP degree never exceeds a node (4 GPUs): cross-node TP would cross
    // the 10 Gbps link twice per layer per pass.
    for u in 0..g.num_layers() {
        assert!(plan.strategy_of(u).tp <= 4, "layer {u}: {:?}", plan.strategy_of(u));
    }
}

/// Table 4/5 shape: DeepSpeed cannot launch on EnvE (B=8, 32 DCUs), and
/// the Megatron exhaustive search takes orders of magnitude longer than
/// UniAP while not beating it.
#[test]
fn table4_enve_shapes() {
    let g = models::llama_7b();
    let profile = Profile::analytic(&ClusterEnv::env_e(), &g);
    let cfg = PlannerConfig::default();
    let ds = Baseline::run(BaselineKind::DeepSpeedZero3, &profile, &g, 8, &cfg);
    assert!(ds.plan.is_none(), "DeepSpeed must SOL× (8 % 32 != 0)");

    let uni = Baseline::run(BaselineKind::UniAP, &profile, &g, 8, &cfg);
    let uni_plan = uni.plan.expect("uniap feasible on EnvE");
    let t_uni = sim_throughput(&g, &profile, &uni_plan).expect("runs");

    let grid = megatron::run(&profile, &g, 8, &cfg);
    let stats = megatron::stats(&grid).expect("some feasible candidates");
    assert!(stats.infeasible > 0, "some Megatron candidates must OOM (Table 5)");
    assert!(
        t_uni >= stats.top1 * 0.95,
        "UniAP should match the exhaustive best: {t_uni} vs {}",
        stats.top1
    );
    assert!(
        grid.simulated_search_secs > 100.0 * uni.opt_secs,
        "exhaustive protocol must dwarf UniAP optimization: {} vs {}",
        grid.simulated_search_secs,
        uni.opt_secs
    );
}

/// §4.2 estimation accuracy: UniAP's own-throughput estimate REE stays
/// small; Galvatron's coarser model mis-estimates more (paper: 3.59% vs
/// 11.17% on average).
#[test]
fn ree_uniap_estimates_better_than_galvatron() {
    let cases = vec![
        (models::bert_huge(), ClusterEnv::env_b(), 16usize),
        (models::vit_huge(), ClusterEnv::env_b(), 64),
    ];
    let cfg = PlannerConfig::default();
    let quiet = SimConfig { jitter: 0.0, iters: 1, ..Default::default() };
    let mut ree_uni = Vec::new();
    let mut ree_gal = Vec::new();
    for (g, env, batch) in cases {
        let profile = Profile::analytic(&env, &g);
        for kind in [BaselineKind::UniAP, BaselineKind::Galvatron] {
            let r = Baseline::run(kind, &profile, &g, batch, &cfg);
            let plan = r.plan.expect("feasible");
            let sim = simulate_plan(&g, &profile, &plan, &quiet);
            let e = uniap::metrics::ree(sim.throughput, plan.est_throughput());
            match kind {
                BaselineKind::UniAP => ree_uni.push(e),
                _ => ree_gal.push(e),
            }
        }
    }
    let mu = uniap::util::mean(&ree_uni);
    let mg = uniap::util::mean(&ree_gal);
    assert!(mu < 0.10, "UniAP avg REE too high: {mu}");
    assert!(mu < mg, "UniAP must estimate better: {mu} vs {mg}");
}
