//! Chaos battery (ISSUE 6): scripted fault plans drive the serving
//! stack through peer resets, stalls, torn snapshot writes, disk-full
//! saves, kill-under-load and overload — asserting the standing
//! invariants the fault layer exists to pin:
//!
//! * **no panic** — every server thread joins `Ok`;
//! * **no corrupt state dir** — a failed save leaves the previous good
//!   snapshot (or nothing), never a torn/zero-length `state.json`;
//! * **no non-typed frame** — whatever goes wrong, clients read a
//!   parseable JSON document with a known `status`;
//! * **bounded time** — silent peers cost the caller's budget, an
//!   overloaded server sheds `busy` promptly instead of queueing;
//! * **byte identity** — plans served after recovery equal the plans
//!   served before the fault, bit for bit.
//!
//! Every test holds a [`fault::FaultGuard`] — either an armed plan via
//! `install` or an explicit `quiesce` — because the plan is process
//! global and the test threads of this binary run concurrently.

use std::io::{BufReader, BufWriter};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use uniap::service::server::{probe_health, serve_frame};
use uniap::service::{
    plan_to_json, CancelToken, LoadOutcome, PlannerService, ServerOptions, Snapshot, Status,
};
use uniap::testing::harness::{bert_req, round_trip, temp_dir, TestServer};
use uniap::util::fault::{self, FaultPlan};
use uniap::util::fsio::write_atomic;
use uniap::util::json::Json;
use uniap::util::net::{
    read_frame, request_response, request_response_retrying, write_frame, Backoff, FrameError,
};

fn plan(spec: &str) -> FaultPlan {
    FaultPlan::parse(spec).expect(spec)
}

fn no_stop() -> bool {
    false
}

// ---------------------------------------------------------------- inert

#[test]
fn quiesced_faults_are_completely_inert() {
    let _guard = fault::quiesce();
    let before = fault::injected_total();
    // fs seam untouched
    let path = temp_dir("chaos", "inert").join("state.txt");
    write_atomic(&path, "payload").expect("quiesced write_atomic");
    assert_eq!(std::fs::read_to_string(&path).unwrap(), "payload");
    // net seams untouched
    let mut out: Vec<u8> = Vec::new();
    write_frame(&mut out, "{\"ok\":1}").unwrap();
    let mut r = BufReader::new(&b"{\"ok\":1}\n"[..]);
    assert_eq!(read_frame(&mut r, 64, &no_stop).unwrap().unwrap(), "{\"ok\":1}");
    // serve seam untouched
    let svc = PlannerService::with_threads(1);
    let out = serve_frame(&svc, r#"{"op":"health"}"#, &CancelToken::new(), 1);
    assert_eq!(Json::parse(&out).unwrap().get("status").and_then(Json::as_str), Some("ok"));
    assert_eq!(fault::injected_total(), before, "nothing may fire while quiesced");
    let _ = std::fs::remove_dir_all(path.parent().unwrap());
}

// ------------------------------------------------------------ net seams

#[test]
fn scripted_resets_and_stalls_hit_read_frame() {
    let guard = fault::install(plan("net.read:reset:x2"));
    let input = b"hello\n".as_slice();
    for _ in 0..2 {
        let mut r = BufReader::new(input);
        match read_frame(&mut r, 64, &no_stop) {
            Err(FrameError::Io(e)) => assert!(e.contains("injected connection reset"), "{e}"),
            other => panic!("expected injected reset, got {other:?}"),
        }
    }
    // budget exhausted (x2) — the third read goes through untouched
    let mut r = BufReader::new(input);
    assert_eq!(read_frame(&mut r, 64, &no_stop).unwrap().unwrap(), "hello");

    // a stall delays the read, then proceeds normally
    guard.set(plan("net.read:stall:150"));
    let t0 = Instant::now();
    let mut r = BufReader::new(input);
    assert_eq!(read_frame(&mut r, 64, &no_stop).unwrap().unwrap(), "hello");
    assert!(t0.elapsed() >= Duration::from_millis(150), "stall must delay");
}

#[test]
fn torn_net_write_emits_a_strict_prefix_then_fails() {
    let guard = fault::install(plan("net.write:torn:5"));
    let mut out: Vec<u8> = Vec::new();
    let err = write_frame(&mut out, "{\"id\":\"x\"}").unwrap_err();
    assert!(err.contains("torn write after 5 bytes"), "{err}");
    assert_eq!(out, b"{\"id\"", "exactly the torn prefix reaches the wire");
    // cleared: the same writer completes the frame
    guard.clear();
    out.clear();
    write_frame(&mut out, "{\"id\":\"x\"}").unwrap();
    assert_eq!(out, b"{\"id\":\"x\"}\n");
}

// --------------------------------------------- client budgets & retries

#[test]
fn silent_peer_costs_the_caller_budget_not_forever() {
    let _guard = fault::quiesce();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let silent = std::thread::spawn(move || {
        // accept, then never reply; hold the socket past the budgets
        let (stream, _) = listener.accept().unwrap();
        std::thread::sleep(Duration::from_millis(1500));
        drop(stream);
    });
    let t0 = Instant::now();
    let err = request_response(&addr, "{\"op\":\"sync\"}", 1 << 16, Duration::from_millis(300))
        .unwrap_err();
    let elapsed = t0.elapsed();
    assert!(err.contains("no reply"), "{err}");
    assert!(elapsed >= Duration::from_millis(290), "budget is the floor: {elapsed:?}");
    assert!(elapsed < Duration::from_secs(2), "budget is (about) the ceiling: {elapsed:?}");
    silent.join().unwrap();
}

#[test]
fn retrying_exchange_stays_within_budget_against_a_silent_peer() {
    let _guard = fault::quiesce();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let silent = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        std::thread::sleep(Duration::from_millis(2000));
        drop(stream);
    });
    let t0 = Instant::now();
    let mut retries = 0u32;
    let err = request_response_retrying(
        &addr,
        "{\"op\":\"health\"}",
        1 << 16,
        Duration::from_millis(600),
        Backoff::default(),
        &mut |_, _| retries += 1,
    )
    .unwrap_err();
    let elapsed = t0.elapsed();
    // the silent peer eats the whole budget in one attempt; the loop
    // must refuse to start a pause that cannot fit and report the count
    assert!(err.contains("gave up after 1 attempt(s)"), "{err}");
    assert_eq!(retries, 0, "no pause fits after a budget-long attempt");
    assert!(elapsed < Duration::from_millis(2500), "bounded: {elapsed:?}");
    silent.join().unwrap();
}

#[test]
fn reset_then_recover_peer_costs_one_retry() {
    let _guard = fault::quiesce();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let peer = std::thread::spawn(move || {
        // first connection: dropped without a byte (reset-shaped)
        let (stream, _) = listener.accept().unwrap();
        drop(stream);
        // second connection: a real reply
        let (stream, _) = listener.accept().unwrap();
        let read_half = stream.try_clone().unwrap();
        let mut reader = BufReader::new(read_half);
        let got = read_frame(&mut reader, 1 << 16, &no_stop).unwrap().unwrap();
        assert!(got.contains("health"), "{got}");
        let mut writer = BufWriter::new(stream);
        write_frame(&mut writer, "pong").unwrap();
    });
    let mut retries = 0u32;
    let reply = request_response_retrying(
        &addr,
        "{\"op\":\"health\"}",
        1 << 16,
        Duration::from_secs(5),
        Backoff { initial: Duration::from_millis(40), max: Duration::from_millis(100) },
        &mut |_, _| retries += 1,
    )
    .expect("second attempt must succeed");
    assert_eq!(reply, "pong");
    assert_eq!(retries, 1, "exactly one retry for one dropped connection");
    peer.join().unwrap();
}

#[test]
fn dead_port_gives_up_within_budget_after_several_attempts() {
    let _guard = fault::quiesce();
    // bind then drop: nothing listens on this port anymore
    let addr = {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    };
    let t0 = Instant::now();
    let mut retries = 0u32;
    let err = request_response_retrying(
        &addr,
        "{\"op\":\"health\"}",
        1 << 16,
        Duration::from_millis(400),
        Backoff { initial: Duration::from_millis(20), max: Duration::from_millis(60) },
        &mut |_, _| retries += 1,
    )
    .unwrap_err();
    let elapsed = t0.elapsed();
    assert!(err.contains("gave up after"), "{err}");
    assert!(retries >= 2, "refused connects are cheap, several attempts fit: {retries}");
    assert!(elapsed < Duration::from_secs(2), "bounded: {elapsed:?}");
}

// -------------------------------------------------- admission & shedding

#[test]
fn overloaded_server_sheds_busy_in_bounded_time_and_recovers() {
    // one in-flight slot; the scripted stall makes its holder slow
    let guard = fault::install(plan("serve.frame:stall:1200"));
    let service = Arc::new(PlannerService::with_threads(2));
    let opts = ServerOptions { max_inflight: 1, ..Default::default() };
    let mut server = TestServer::start(service.clone(), opts);

    // client A occupies the only slot (its frame stalls 1.2 s)
    let addr = server.addr;
    let slow = std::thread::spawn(move || {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
        let read_half = stream.try_clone().unwrap();
        let mut reader = BufReader::new(read_half);
        let mut writer = BufWriter::new(stream);
        round_trip(&mut reader, &mut writer, &bert_req("slow").to_json().to_string())
    });
    std::thread::sleep(Duration::from_millis(300)); // let A claim the slot

    // client B must be shed promptly with a typed busy frame
    let (mut reader, mut writer) = server.connect();
    let t0 = Instant::now();
    let resp = round_trip(&mut reader, &mut writer, &bert_req("shed-me").to_json().to_string());
    assert_eq!(resp.status, Status::Busy, "{resp:?}");
    assert!(resp.error.unwrap().contains("in-flight cap"), "names the cap");
    assert!(t0.elapsed() < Duration::from_secs(1), "shed in bounded time: {:?}", t0.elapsed());

    // while the stalled plan frame still holds the only permit, health
    // and stats probes bypass admission control (ISSUE 8 satellite) —
    // the ops an operator needs most while a node sheds load
    for probe in [r#"{"op":"health"}"#, r#"{"op":"stats"}"#] {
        write_frame(&mut writer, probe).unwrap();
        let line = read_frame(&mut reader, 1 << 16, &no_stop).unwrap().unwrap();
        let doc = Json::parse(&line).unwrap();
        assert_eq!(
            doc.get("status").and_then(Json::as_str),
            Some("ok"),
            "{probe} must answer while saturated: {line}"
        );
    }

    // the slow client still gets its real answer, and the connection B
    // used stays usable once the slot frees up
    let slow_resp = slow.join().expect("client thread");
    assert_eq!(slow_resp.status, Status::Ok, "{slow_resp:?}");
    guard.clear();
    let resp = round_trip(&mut reader, &mut writer, &bert_req("after-shed").to_json().to_string());
    assert_eq!(resp.status, Status::Ok);

    server.stop().expect("clean shutdown");
    let stats = service.stats();
    assert!(stats.requests_shed >= 1, "{stats:?}");
    assert!(stats.faults_injected >= 1, "the stall plan must actually have fired: {stats:?}");
}

#[test]
fn connection_cap_sheds_with_one_busy_frame_then_closes() {
    let _guard = fault::quiesce();
    let opts = ServerOptions { max_connections: 0, ..Default::default() };
    let service = Arc::new(PlannerService::with_threads(1));
    let mut server = TestServer::start(service.clone(), opts);
    let stream = TcpStream::connect(server.addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut reader = BufReader::new(stream);
    // the server speaks first: one busy frame, then a close
    let line = read_frame(&mut reader, 1 << 16, &no_stop).expect("read").expect("busy frame");
    let resp = uniap::service::PlanResponse::parse(&line).expect("typed busy");
    assert_eq!(resp.status, Status::Busy);
    assert!(resp.error.unwrap().contains("connections cap"), "names the cap");
    match read_frame(&mut reader, 1 << 16, &no_stop) {
        Ok(None) | Err(FrameError::Io(_)) => {} // closed (EOF or RST race)
        other => panic!("connection must be closed after the shed, got {other:?}"),
    }
    server.stop().expect("clean shutdown");
    assert!(service.stats().requests_shed >= 1);
}

// ------------------------------------------------------ snapshot faults

#[test]
fn failed_saves_never_corrupt_the_state_dir() {
    let guard = fault::quiesce();
    let svc = PlannerService::with_threads(2);
    let req = bert_req("persist");
    let want = plan_to_json(svc.plan(&req).plan.as_ref().unwrap()).to_string();

    for spec in ["fs.write:torn:20", "fs.write:full", "fs.rename:fail"] {
        let dir = temp_dir("chaos", &format!("save-{}", spec.replace([':', '.'], "-")));
        guard.set(plan(spec));
        let err = svc.save_state(&dir).expect_err(spec);
        assert!(err.contains("injected"), "{spec}: {err}");
        // nothing half-written: no merged snapshot, no temp litter
        assert!(!dir.join("state.json").exists(), "{spec}: torn state.json left behind");
        let litter: Vec<String> = std::fs::read_dir(&dir)
            .map(|entries| {
                entries
                    .filter_map(|e| e.ok())
                    .map(|e| e.file_name().to_string_lossy().into_owned())
                    .filter(|n| n.contains(".tmp."))
                    .collect()
            })
            .unwrap_or_default();
        assert!(litter.is_empty(), "{spec}: temp litter {litter:?}");

        // cleared: the very same dir accepts a clean save, and a fresh
        // service recovers byte-identical plans from it
        guard.clear();
        svc.save_state(&dir).expect("clean save after fault");
        let fresh = PlannerService::with_threads(2);
        assert!(matches!(fresh.load_state(&dir), LoadOutcome::Loaded { .. }));
        let resp = fresh.plan(&req);
        assert_eq!(resp.cache.base_misses, 0, "{spec}: recovered state must cover the sweep");
        assert_eq!(
            plan_to_json(resp.plan.as_ref().unwrap()).to_string(),
            want,
            "{spec}: byte-identical after recovery"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn torn_resave_preserves_the_previous_good_snapshot() {
    let guard = fault::quiesce();
    let dir = temp_dir("chaos", "resave");
    let svc = PlannerService::with_threads(2);
    assert_eq!(svc.plan(&bert_req("v1")).status, Status::Ok);
    svc.save_state(&dir).expect("baseline save");
    let v1 = std::fs::read_to_string(dir.join("state.json")).unwrap();
    let v1_counts = Snapshot::parse(&v1).expect("baseline validates").counts();

    // grow the state so the next save is not skipped as unchanged, then
    // tear every write: the published snapshot must remain the old one
    let mut bigger = bert_req("v2");
    bigger.batch = 32;
    assert_eq!(svc.plan(&bigger).status, Status::Ok);
    guard.set(plan("fs.write:torn:10:x*"));
    let err = svc.save_state(&dir).expect_err("torn save must fail");
    assert!(err.contains("torn"), "{err}");
    let after = std::fs::read_to_string(dir.join("state.json")).expect("state.json still there");
    assert_eq!(after, v1, "old-or-new: a torn save may not touch the published bytes");
    assert_eq!(Snapshot::parse(&after).unwrap().counts(), v1_counts);

    // cleared: the grown state publishes
    guard.clear();
    svc.save_state(&dir).expect("clean save");
    let v2_counts = Snapshot::parse(&std::fs::read_to_string(dir.join("state.json")).unwrap())
        .unwrap()
        .counts();
    assert!(v2_counts.0 >= v1_counts.0 && v2_counts.1 >= v1_counts.1, "state only grows");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn injected_load_failures_degrade_to_a_cold_start_not_a_crash() {
    let guard = fault::quiesce();
    let dir = temp_dir("chaos", "load");
    let svc = PlannerService::with_threads(2);
    assert_eq!(svc.plan(&bert_req("seed")).status, Status::Ok);
    svc.save_state(&dir).expect("save");

    guard.set(plan("snapshot.load:fail:x*"));
    let fresh = PlannerService::with_threads(2);
    match fresh.load_state(&dir) {
        LoadOutcome::ColdStart { reason: Some(why) } => {
            assert!(why.contains("injected"), "{why}")
        }
        other => panic!("sick disk must degrade to a reasoned cold start, got {other:?}"),
    }
    // the same directory loads fine once the disk recovers
    guard.clear();
    assert!(matches!(fresh.load_state(&dir), LoadOutcome::Loaded { .. }));
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------------------- kill under load

#[test]
fn kill_under_load_restarts_clean_despite_a_truncated_sibling() {
    let _guard = fault::quiesce();
    let dir = temp_dir("chaos", "kill");
    let opts = ServerOptions { state_dir: Some(dir.clone()), ..Default::default() };

    // generation 1: capture reference bytes, then die mid-load
    let reference;
    {
        let mut server =
            TestServer::start(Arc::new(PlannerService::with_threads(2)), opts.clone());
        let (mut reader, mut writer) = server.connect();
        let resp = round_trip(&mut reader, &mut writer, &bert_req("ref").to_json().to_string());
        assert_eq!(resp.status, Status::Ok);
        reference = plan_to_json(resp.plan.as_ref().unwrap()).to_string();

        // three clients hammer valid + garbage frames while we cancel
        let addr = server.addr;
        let hammers: Vec<_> = (0..3)
            .map(|i| {
                std::thread::spawn(move || {
                    let Ok(stream) = TcpStream::connect(addr) else { return };
                    let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
                    let Ok(read_half) = stream.try_clone() else { return };
                    let mut reader = BufReader::new(read_half);
                    let mut writer = BufWriter::new(stream);
                    for n in 0..50 {
                        let frame = match (i + n) % 3 {
                            0 => bert_req(&format!("h{i}-{n}")).to_json().to_string(),
                            1 => "{ mangled".to_string(),
                            _ => r#"{"op":"health"}"#.to_string(),
                        };
                        if write_frame(&mut writer, &frame).is_err() {
                            return; // server went away mid-load: expected
                        }
                        // replies may be typed responses, health docs, or
                        // never arrive (cancelled) — anything but a panic
                        let _ = read_frame(&mut reader, 1 << 24, &no_stop);
                    }
                })
            })
            .collect();
        std::thread::sleep(Duration::from_millis(300));
        server.shutdown.cancel(); // the "kill", mid-load
        assert!(server.stop().is_ok(), "killed-under-load server must join cleanly");
        for h in hammers {
            h.join().expect("hammer thread must not panic");
        }
        assert!(dir.join("state.json").exists(), "shutdown snapshot written");
    }

    // corrupt the directory the way a crashed sibling would: a torn
    // generation file next to the good merged snapshot
    let good = std::fs::read_to_string(dir.join("state.json")).unwrap();
    std::fs::write(dir.join("state.crashed.json"), &good[..good.len() / 2]).unwrap();

    // generation 2: clean restart, warm, byte-identical
    let service = Arc::new(PlannerService::with_threads(2));
    assert!(matches!(service.load_state(&dir), LoadOutcome::Loaded { .. }));
    let mut server = TestServer::start(service.clone(), opts);
    let (mut reader, mut writer) = server.connect();
    let resp = round_trip(&mut reader, &mut writer, &bert_req("gen2").to_json().to_string());
    assert_eq!(resp.status, Status::Ok);
    assert_eq!(
        plan_to_json(resp.plan.as_ref().unwrap()).to_string(),
        reference,
        "recovery must serve the exact bytes from before the kill"
    );
    server.stop().expect("clean shutdown");
    assert!(service.stats().persisted_frontier_hits > 0, "{:?}", service.stats());
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------------------- health & resync

#[test]
fn health_probe_distinguishes_up_from_down() {
    let _guard = fault::quiesce();
    let mut server =
        TestServer::start(Arc::new(PlannerService::with_threads(1)), ServerOptions::default());
    let addr = server.addr.to_string();
    probe_health(&addr, Duration::from_secs(2)).expect("live server is ready");

    // raw frame shape: status/connections/requests
    let (mut reader, mut writer) = server.connect();
    write_frame(&mut writer, r#"{"op":"health"}"#).unwrap();
    let line = read_frame(&mut reader, 1 << 16, &no_stop).unwrap().unwrap();
    let doc = Json::parse(&line).unwrap();
    assert_eq!(doc.get("status").and_then(Json::as_str), Some("ok"));
    assert!(doc.get("connections").and_then(Json::as_usize).is_some());
    assert!(doc.get("requests").and_then(Json::as_usize).is_some());
    server.stop().expect("clean shutdown");

    // a dead port fails fast, within the probe timeout
    let dead = {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    };
    let t0 = Instant::now();
    assert!(probe_health(&dead, Duration::from_secs(2)).is_err());
    assert!(t0.elapsed() < Duration::from_secs(2), "refused connect is fast");
}

#[test]
fn background_resync_tick_warms_a_server_from_its_peer() {
    let _guard = fault::quiesce();
    // peer A: warm before B boots
    let a_service = Arc::new(PlannerService::with_threads(2));
    let req = bert_req("warm");
    let want = plan_to_json(a_service.plan(&req).plan.as_ref().unwrap()).to_string();
    let mut a = TestServer::start(a_service, ServerOptions::default());

    // B: no boot sync (that's the CLI's job) — only the background tick
    let b_service = Arc::new(PlannerService::with_threads(2));
    let opts = ServerOptions {
        sync_from: Some(a.addr.to_string()),
        resync_secs: 0.05,
        ..Default::default()
    };
    let mut b = TestServer::start(b_service.clone(), opts);
    let t0 = Instant::now();
    while b_service.stats().persisted_frontiers_loaded == 0 {
        assert!(t0.elapsed() < Duration::from_secs(5), "tick never pulled the peer snapshot");
        std::thread::sleep(Duration::from_millis(20));
    }
    // warmed purely in the background: same bytes, no rebuild
    let resp = b_service.plan(&req);
    assert_eq!(resp.cache.base_misses, 0, "{:?}", resp.cache);
    assert_eq!(plan_to_json(resp.plan.as_ref().unwrap()).to_string(), want);
    b.stop().expect("clean shutdown");
    a.stop().expect("clean shutdown");
}

#[test]
fn resync_tick_backs_off_while_the_peer_is_down_and_keeps_serving() {
    let _guard = fault::quiesce();
    let dead = {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    };
    let service = Arc::new(PlannerService::with_threads(2));
    let opts =
        ServerOptions { sync_from: Some(dead), resync_secs: 0.05, ..Default::default() };
    let mut server = TestServer::start(service.clone(), opts);
    let t0 = Instant::now();
    while service.stats().sync_retries == 0 {
        assert!(t0.elapsed() < Duration::from_secs(5), "failed pulls must be counted");
        std::thread::sleep(Duration::from_millis(20));
    }
    // a down peer costs warmth, never availability
    let (mut reader, mut writer) = server.connect();
    let resp = round_trip(&mut reader, &mut writer, &bert_req("alive").to_json().to_string());
    assert_eq!(resp.status, Status::Ok);
    server.stop().expect("clean shutdown despite the dead peer");
    assert!(service.stats().sync_retries >= 1);
}
