//! Fleet battery (ISSUE 8): several real `serve --listen` servers joined
//! into one consistent-hash ring, driven over loopback TCP — asserting
//! the standing invariants the fleet layer exists to pin:
//!
//! * **deterministic routing** — every node, whatever the order of its
//!   `--peers` list, names the same owner for every key, so each key is
//!   cold-solved exactly once fleet-wide;
//! * **warm forwarding** — a non-owner answers a miss with the owner's
//!   bytes and adopts them, so its second hit is local;
//! * **failover** — a dead owner degrades the receiving node to a local
//!   solve with the *same* bytes (membership changes who computes a
//!   response, never the response);
//! * **gossip convergence** — nodes converge via the anti-entropy tick
//!   alone: a restarted (or late-started) node re-warms with no boot
//!   sync and no client traffic, and a dead peer in the rotation never
//!   stalls the live ones.
//!
//! No test here arms a fault plan, so no [`fault`] guard is needed —
//! the chaos is real process/kill-level chaos, not injected I/O faults.

use std::io::{BufReader, BufWriter};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use uniap::cluster::ClusterEnv;
use uniap::service::{
    plan_to_json, resolve_workload, workload_fingerprint_tagged, PlanRequest, PlannerService,
    Ring, Server, ServerOptions, Status,
};
use uniap::testing::harness::{bert_req, round_trip, TestServer};

/// The fingerprint the ring routes on — recomputed exactly the way the
/// serving path computes it.
fn fp_of(req: &PlanRequest) -> u64 {
    let env = ClusterEnv::by_name(&req.env).expect("test env");
    let w = resolve_workload(req).expect("test workload");
    workload_fingerprint_tagged(w.kind, &env, &w.graph)
}

/// Index (into `addrs`) of the node owning `fp`.
fn owner_index(addrs: &[String], fp: u64) -> usize {
    let ring = Ring::new(addrs).expect("ring");
    let owner = ring.owner_of(fp).to_string();
    addrs.iter().position(|a| *a == owner).expect("owner is a member")
}

/// `addrs` rotated by `k` — same membership set, different list order.
fn rotated(addrs: &[String], k: usize) -> Vec<String> {
    (0..addrs.len()).map(|i| addrs[(i + k) % addrs.len()].clone()).collect()
}

/// Bind `n` ephemeral listeners first (so every node can be told the
/// full membership), then start them all as one fleet. Each node gets
/// the peer list rotated by its own index: the battery's standing check
/// that ring construction is order-insensitive.
fn fleet_of(n: usize, resync_secs: f64) -> (Vec<TestServer>, Vec<String>) {
    let servers: Vec<Server> =
        (0..n).map(|_| Server::bind("127.0.0.1:0").expect("ephemeral bind")).collect();
    let addrs: Vec<String> = servers.iter().map(|s| s.local_addr().to_string()).collect();
    let nodes = servers
        .into_iter()
        .enumerate()
        .map(|(i, server)| {
            let opts = ServerOptions {
                peers: rotated(&addrs, i),
                advertise: Some(addrs[i].clone()),
                resync_secs,
                ..Default::default()
            };
            TestServer::start_on(Arc::new(PlannerService::with_threads(2)), opts, server)
        })
        .collect();
    (nodes, addrs)
}

/// One request over a fresh connection to `addr` (thread-friendly:
/// everything owned).
fn request_at(addr: std::net::SocketAddr, frame: &str) -> uniap::service::PlanResponse {
    let stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    let read_half = stream.try_clone().unwrap();
    let mut reader = BufReader::new(read_half);
    let mut writer = BufWriter::new(stream);
    round_trip(&mut reader, &mut writer, frame)
}

fn plan_bytes(resp: &uniap::service::PlanResponse) -> String {
    plan_to_json(resp.plan.as_ref().expect("plan")).to_string()
}

fn stop_all(nodes: &mut [TestServer]) {
    for n in nodes {
        n.stop().expect("clean shutdown");
    }
}

// ------------------------------------------------------- warm forwarding

#[test]
fn forwarded_miss_is_solved_by_the_owner_and_adopted() {
    let (mut nodes, addrs) = fleet_of(3, 0.0); // routing only, no gossip
    let req = bert_req("fleet-forward");
    let frame = req.to_json().to_string();
    let owner = owner_index(&addrs, fp_of(&req));
    let receiver = (owner + 1) % nodes.len();

    // the miss lands on a non-owner: answered with the owner's bytes
    let resp = request_at(nodes[receiver].addr, &frame);
    assert_eq!(resp.status, Status::Ok, "{resp:?}");
    let want = plan_bytes(&resp);

    let rs = nodes[receiver].service.stats();
    let os = nodes[owner].service.stats();
    assert_eq!(rs.forwards, 1, "the receiver forwarded, {rs:?}");
    assert_eq!(rs.forward_fallbacks, 0, "{rs:?}");
    assert_eq!(rs.plan_misses, 0, "the receiver adopted, it never solved: {rs:?}");
    assert_eq!(os.plan_misses, 1, "exactly one cold solve, at the owner: {os:?}");

    // the second hit on the same node replays the adopted outcome
    let resp2 = request_at(nodes[receiver].addr, &frame);
    assert_eq!(resp2.status, Status::Ok);
    assert_eq!(plan_bytes(&resp2), want, "adoption preserves the exact bytes");
    let rs2 = nodes[receiver].service.stats();
    assert_eq!(rs2.forwards, 1, "no second forward for a warm key: {rs2:?}");
    assert!(rs2.plan_hits >= 1, "{rs2:?}");

    // the owner's own answer for the key: the same bytes
    let resp3 = request_at(nodes[owner].addr, &frame);
    assert_eq!(plan_bytes(&resp3), want);
    stop_all(&mut nodes);
}

#[test]
fn every_peer_ordering_routes_to_the_same_owner() {
    // fleet_of already hands each node a differently-rotated peer list;
    // with any disagreement about ownership, either two nodes solve the
    // key (≥ 2 misses) or a forward bounces (relay solves locally, but
    // forwards would exceed the fleet's non-owner count)
    let (mut nodes, _addrs) = fleet_of(3, 0.0);
    let req = bert_req("fleet-deterministic");
    let frame = req.to_json().to_string();
    let mut bytes = Vec::new();
    for node in &nodes {
        let resp = request_at(node.addr, &frame);
        assert_eq!(resp.status, Status::Ok, "{resp:?}");
        bytes.push(plan_bytes(&resp));
    }
    assert!(bytes.windows(2).all(|w| w[0] == w[1]), "one answer fleet-wide");
    let misses: usize = nodes.iter().map(|n| n.service.stats().plan_misses).sum();
    let forwards: usize = nodes.iter().map(|n| n.service.stats().forwards).sum();
    assert_eq!(misses, 1, "exactly one node considered the key its own");
    assert_eq!(forwards, nodes.len() - 1, "every non-owner forwarded exactly once");
    stop_all(&mut nodes);
}

// ------------------------------------------------------------- failover

#[test]
fn dead_owner_degrades_to_a_local_solve_with_identical_bytes() {
    let (mut nodes, addrs) = fleet_of(3, 0.0);
    let req = bert_req("fleet-fallback");
    let frame = req.to_json().to_string();
    let owner = owner_index(&addrs, fp_of(&req));
    let receiver = (owner + 1) % nodes.len();

    // reference bytes from an offline service: the planner is
    // deterministic, so "who computes it" must never change the answer
    let reference =
        plan_bytes(&PlannerService::with_threads(2).plan(&req));

    nodes[owner].stop().expect("owner kill");
    let t0 = Instant::now();
    let resp = request_at(nodes[receiver].addr, &frame);
    assert_eq!(resp.status, Status::Ok, "survivors must keep answering: {resp:?}");
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "fallback is bounded by the forward budget: {:?}",
        t0.elapsed()
    );
    assert_eq!(plan_bytes(&resp), reference, "failover may not change the bytes");
    let rs = nodes[receiver].service.stats();
    assert!(rs.forward_fallbacks >= 1, "the degraded forward is counted: {rs:?}");
    assert_eq!(rs.plan_misses, 1, "the receiver solved the key itself: {rs:?}");

    // the suspicion window makes the *next* miss skip the dead owner
    // without paying the connect budget again
    let mut req2 = bert_req("fleet-fallback-2");
    req2.batch = 32; // a different key, same (likely) owner or not — either
    let resp2 = request_at(nodes[receiver].addr, &req2.to_json().to_string());
    assert_eq!(resp2.status, Status::Ok);
    stop_all(&mut nodes);
}

#[test]
fn warm_fleet_survives_an_owner_kill_with_zero_cold_solves() {
    let (mut nodes, addrs) = fleet_of(3, 0.0);
    let req = bert_req("fleet-acceptance");
    let frame = req.to_json().to_string();
    let owner = owner_index(&addrs, fp_of(&req));

    // warm-up: one request per node; the owner cold-solves exactly once
    // and both non-owners adopt the forwarded bytes
    let mut bytes = Vec::new();
    for node in &nodes {
        let resp = request_at(node.addr, &frame);
        assert_eq!(resp.status, Status::Ok, "{resp:?}");
        bytes.push(plan_bytes(&resp));
    }
    assert!(bytes.windows(2).all(|w| w[0] == w[1]), "one answer fleet-wide");
    let want = bytes[0].clone();
    let misses: usize = nodes.iter().map(|n| n.service.stats().plan_misses).sum();
    assert_eq!(misses, 1, "warm-up costs exactly one cold solve fleet-wide");

    // kill the owner abruptly, then load the survivors concurrently
    nodes[owner].shutdown.cancel();
    nodes[owner].stop().expect("killed owner joins");
    let survivors: Vec<usize> =
        (0..nodes.len()).filter(|&i| i != owner).collect();
    let handles: Vec<_> = survivors
        .iter()
        .flat_map(|&i| {
            let addr = nodes[i].addr;
            let frame = frame.clone();
            (0..3).map(move |_| {
                let frame = frame.clone();
                std::thread::spawn(move || request_at(addr, &frame))
            })
        })
        .collect();
    for h in handles {
        let resp = h.join().expect("client thread");
        assert_eq!(resp.status, Status::Ok, "survivor under load: {resp:?}");
        assert_eq!(plan_bytes(&resp), want, "byte-identical after the kill");
    }
    for &i in &survivors {
        let s = nodes[i].service.stats();
        assert_eq!(s.plan_misses, 0, "zero cold solves on node {i} after warm-up: {s:?}");
    }
    stop_all(&mut nodes);
}

// ---------------------------------------------------- gossip anti-entropy

#[test]
fn gossip_warms_peers_and_catches_up_a_late_started_node() {
    // bind all three first; C's address is on every ring from the start,
    // but C itself boots late — the "restarted node" of the failover
    // story, caught up by its own gossip tick alone
    let servers: Vec<Server> =
        (0..3).map(|_| Server::bind("127.0.0.1:0").expect("bind")).collect();
    let addrs: Vec<String> = servers.iter().map(|s| s.local_addr().to_string()).collect();
    let opts_for = |i: usize| ServerOptions {
        peers: rotated(&addrs, i),
        advertise: Some(addrs[i].clone()),
        resync_secs: 0.05,
        ..Default::default()
    };
    let mut it = servers.into_iter();
    let (sa, sb, sc) = (it.next().unwrap(), it.next().unwrap(), it.next().unwrap());
    let mut a = TestServer::start_on(Arc::new(PlannerService::with_threads(2)), opts_for(0), sa);
    let mut b = TestServer::start_on(Arc::new(PlannerService::with_threads(2)), opts_for(1), sb);

    // warm A locally — no client ever talks to B or C in this test
    let req = bert_req("fleet-gossip");
    let resp = a.service.plan(&req);
    assert_eq!(resp.status, Status::Ok);
    let want = plan_bytes(&resp);

    // B converges through the tick, with the still-dead C in rotation
    let t0 = Instant::now();
    while b.service.stats().gossip_merged_entries == 0 {
        assert!(t0.elapsed() < Duration::from_secs(10), "B never converged via gossip");
        std::thread::sleep(Duration::from_millis(20));
    }
    let respb = b.service.plan(&req);
    assert_eq!(respb.cache.base_misses, 0, "gossip must have carried the cost base");
    assert_eq!(plan_bytes(&respb), want, "gossip-warmed bytes are identical");

    // C boots late on its pre-bound socket and re-warms the same way
    let mut c = TestServer::start_on(Arc::new(PlannerService::with_threads(2)), opts_for(2), sc);
    let t0 = Instant::now();
    while c.service.stats().gossip_merged_entries == 0 {
        assert!(t0.elapsed() < Duration::from_secs(10), "late node never caught up");
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(c.service.stats().gossip_rounds >= 1);
    let respc = c.service.plan(&req);
    assert_eq!(respc.cache.base_misses, 0, "a (re)started node re-warms by gossip alone");
    assert_eq!(plan_bytes(&respc), want);
    c.stop().expect("clean shutdown");
    b.stop().expect("clean shutdown");
    a.stop().expect("clean shutdown");
}

#[test]
fn gossip_routes_around_a_dead_peer_and_keeps_serving() {
    // two live nodes + one permanently dead address on the ring
    let dead = {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    };
    let servers: Vec<Server> =
        (0..2).map(|_| Server::bind("127.0.0.1:0").expect("bind")).collect();
    let mut addrs: Vec<String> = servers.iter().map(|s| s.local_addr().to_string()).collect();
    addrs.push(dead);
    let mut it = servers.into_iter();
    let (sa, sb) = (it.next().unwrap(), it.next().unwrap());
    let opts_for = |i: usize| ServerOptions {
        peers: addrs.clone(),
        advertise: Some(addrs[i].clone()),
        resync_secs: 0.05,
        ..Default::default()
    };
    let mut a = TestServer::start_on(Arc::new(PlannerService::with_threads(2)), opts_for(0), sa);
    let mut b = TestServer::start_on(Arc::new(PlannerService::with_threads(2)), opts_for(1), sb);

    let req = bert_req("fleet-dead-peer");
    let resp = a.service.plan(&req);
    assert_eq!(resp.status, Status::Ok);
    let want = plan_bytes(&resp);

    // B converges from A despite the dead member in its rotation — the
    // suspicion window steers every later round at the live peer
    let t0 = Instant::now();
    while b.service.stats().gossip_merged_entries == 0 {
        assert!(t0.elapsed() < Duration::from_secs(10), "dead peer stalled the rotation");
        std::thread::sleep(Duration::from_millis(20));
    }
    let respb = b.service.plan(&req);
    assert_eq!(respb.cache.base_misses, 0);
    assert_eq!(plan_bytes(&respb), want);

    // and a dead ring member costs warmth of its key range only, never
    // availability: B still answers sockets
    let socket_resp = request_at(b.addr, &bert_req("fleet-dead-peer-live").to_json().to_string());
    assert!(
        matches!(socket_resp.status, Status::Ok | Status::Busy),
        "typed response while gossiping around a dead peer: {socket_resp:?}"
    );
    b.stop().expect("clean shutdown");
    a.stop().expect("clean shutdown");
}
