//! Integration + property tests for the planner service layer: JSON
//! round-trips over randomized values (via the `testing::` PRNG), the
//! warm-vs-cold cache equivalence guarantee, and batch serving with
//! per-request deadlines.

use uniap::cost::Schedule;
use uniap::planner::uop::CandidateLog;
use uniap::planner::PlannerConfig;
use uniap::service::{
    plan_from_json, plan_to_json, CacheStats, CancelToken, PlanRequest, PlanResponse,
    PlannerService, Status, Timings,
};
use uniap::testing::{
    self,
    gen::{random_plan, random_request},
};
use uniap::util::json::Json;

#[test]
fn plan_json_roundtrip_property() {
    testing::check("plan_json_roundtrip", 60, random_plan, |plan| {
        let text = plan_to_json(plan).to_string();
        let back = plan_from_json(&Json::parse(&text).map_err(|e| e.to_string())?)
            .map_err(|e| format!("reparse failed: {e}"))?;
        let again = plan_to_json(&back).to_string();
        if again != text {
            return Err(format!("emit∘parse not identity:\n  {text}\n  {again}"));
        }
        if back.est_tpi.to_bits() != plan.est_tpi.to_bits() {
            return Err("est_tpi lost precision".to_string());
        }
        Ok(())
    });
}

#[test]
fn request_json_roundtrip_property() {
    testing::check("request_json_roundtrip", 60, random_request, |req| {
        let text = req.to_json().to_string();
        let back = PlanRequest::parse(&text).map_err(|e| e.to_string())?;
        if &back != req {
            return Err(format!("{back:?} != {req:?}"));
        }
        // pretty emission must parse identically
        let pretty = PlanRequest::parse(&req.to_json().to_pretty()).map_err(|e| e.to_string())?;
        if &pretty != req {
            return Err("pretty form diverged".to_string());
        }
        Ok(())
    });
}

#[test]
fn response_json_roundtrip_property() {
    testing::check(
        "response_json_roundtrip",
        40,
        |rng| {
            let status = *rng.pick(&[
                Status::Ok,
                Status::Infeasible,
                Status::Cancelled,
                Status::DeadlineExceeded,
            ]);
            let plan = (status == Status::Ok).then(|| random_plan(rng));
            let log = (0..rng.usize_in(0, 6))
                .map(|_| CandidateLog {
                    pp_size: *rng.pick(&[1usize, 2, 4, 8]),
                    num_micro: *rng.pick(&[2usize, 4, 8]),
                    // infeasible outcomes included (ISSUE 4): an INFINITY
                    // cost must survive the wire via the "inf" sentinel
                    tpi: rng.bool(0.7).then(|| {
                        if rng.bool(0.2) {
                            f64::INFINITY
                        } else {
                            rng.f64_in(1e-3, 5.0)
                        }
                    }),
                    solve_secs: rng.f64_in(0.0, 2.0),
                })
                .collect();
            PlanResponse {
                id: format!("r{}", rng.usize_in(0, 100)),
                status,
                error: (status == Status::Infeasible).then(|| "SOL×".to_string()),
                plan,
                log,
                timings: Timings {
                    total_secs: rng.f64_in(0.0, 3.0),
                    profile_secs: rng.f64_in(0.0, 0.5),
                    solve_secs: rng.f64_in(0.0, 2.0),
                },
                cache: CacheStats {
                    profile_hits: rng.usize_in(0, 2),
                    profile_misses: rng.usize_in(0, 2),
                    base_hits: rng.usize_in(0, 6),
                    base_misses: rng.usize_in(0, 6),
                    plan_hits: rng.usize_in(0, 2),
                    plan_misses: rng.usize_in(0, 2),
                },
            }
        },
        |resp| {
            let text = resp.to_json().to_string();
            let back = PlanResponse::parse(&text).map_err(|e| e.to_string())?;
            if back.to_json().to_string() != text {
                return Err("emit∘parse not identity".to_string());
            }
            Ok(())
        },
    );
}

/// The acceptance guarantee: a warm repeated request returns a plan
/// byte-identical (as canonical JSON) to the cold-cache solve, for both
/// the outcome-cache path (strict repeat) and the cost-base path
/// (different schedule).
#[test]
fn warm_cache_equivalence_is_byte_identical() {
    let mut req = PlanRequest::new("equiv", "bert", "EnvB", 16);
    req.max_pp = Some(2);

    let shared = PlannerService::with_threads(2);
    let cold = shared.plan(&req);
    assert_eq!(cold.status, Status::Ok);
    let cold_bytes = plan_to_json(cold.plan.as_ref().unwrap()).to_string();

    // strict repeat → outcome replay
    let repeat = shared.plan(&req);
    assert_eq!(repeat.cache.plan_hits, 1);
    assert_eq!(plan_to_json(repeat.plan.as_ref().unwrap()).to_string(), cold_bytes);

    // same bases, different schedule → solved warm; must equal the plan a
    // completely fresh service produces for that request
    let mut variant = req.clone();
    variant.schedule = Schedule::OneF1B;
    variant.id = "variant".into();
    let warm_variant = shared.plan(&variant);
    assert_eq!(warm_variant.status, Status::Ok);
    assert!(warm_variant.cache.fully_warm(), "{:?}", warm_variant.cache);
    let fresh_variant = PlannerService::with_threads(2).plan(&variant);
    assert_eq!(
        plan_to_json(warm_variant.plan.as_ref().unwrap()).to_string(),
        plan_to_json(fresh_variant.plan.as_ref().unwrap()).to_string(),
        "warm solve must be byte-identical to a cold solve"
    );

    // and the service path must agree with the raw planner API
    let env = uniap::cluster::ClusterEnv::env_b();
    let graph = uniap::graph::models::bert_huge();
    let profile = uniap::profiling::Profile::analytic(&env, &graph);
    let cfg = PlannerConfig { max_pp: Some(2), threads: 2, ..Default::default() };
    let direct = uniap::planner::uop(&profile, &graph, 16, &cfg).best.expect("feasible");
    assert_eq!(plan_to_json(&direct).to_string(), cold_bytes, "service == uop()");
}

#[test]
fn serve_honours_per_request_deadlines_in_a_batch() {
    let mut ok_req = PlanRequest::new("ok", "bert", "EnvB", 16);
    ok_req.max_pp = Some(2);
    let mut doomed = ok_req.clone();
    doomed.id = "doomed".into();
    doomed.deadline_secs = Some(1e-9);

    let svc = PlannerService::with_threads(2);
    let resps = svc.serve(&[ok_req, doomed], 2);
    assert_eq!(resps.len(), 2);
    assert_eq!(resps[0].id, "ok");
    assert_eq!(resps[0].status, Status::Ok);
    assert_eq!(resps[1].id, "doomed");
    assert_eq!(resps[1].status, Status::DeadlineExceeded);
    assert!(resps[1].plan.is_none());
}

/// ISSUE 3 satellite: a token fired mid-solve must stop every row-parallel
/// DP worker promptly, and the truncated outcome must never enter the
/// replay cache.
#[test]
fn cancel_mid_solve_stops_row_parallel_workers_and_never_caches() {
    // Swin-Huge (50 layers) at B=128 is the heaviest sweep in the zoo —
    // 8 candidates even under max_pp=2 — so a 5 ms cancel always lands
    // mid-solve while the interval rows are fanned out.
    let mut req = PlanRequest::new("mid", "swin", "EnvA", 128);
    req.max_pp = Some(2);
    req.threads = Some(2); // leave budget spare so rows fan out
    let svc = PlannerService::with_threads(2);
    let token = CancelToken::new();
    let t0 = std::time::Instant::now();
    let resp = std::thread::scope(|scope| {
        let handle = scope.spawn(|| svc.plan_cancellable(&req, &token, None));
        std::thread::sleep(std::time::Duration::from_millis(5));
        token.cancel();
        handle.join().expect("solver thread must not panic")
    });
    let elapsed = t0.elapsed().as_secs_f64();
    // Promptness: the DP polls the token once per row step, so the stop
    // must land orders of magnitude before a full Swin solve would.
    assert!(elapsed < 30.0, "cancel not honoured promptly: {elapsed}s");
    // The sweep was truly truncated: at least one candidate unsolved.
    assert!(
        resp.log.iter().any(|l| l.tpi.is_none()),
        "cancel landed after the whole sweep finished — workload too small"
    );
    // A truncated sweep may still carry a best-effort incumbent (then it
    // reports Ok); with no plan the cause must surface as Cancelled.
    if resp.plan.is_none() {
        assert_eq!(resp.status, Status::Cancelled);
    }
    // Never cache the truncated outcome: nothing may be replayable.
    assert_eq!(svc.stats().cached_plans, 0, "truncated outcome was cached");
    assert_eq!(svc.stats().plan_hits, 0);
}

#[test]
fn serve_cancellable_stops_the_whole_batch() {
    let mut req = PlanRequest::new("x", "bert", "EnvB", 16);
    req.max_pp = Some(2);
    let token = CancelToken::new();
    token.cancel();
    let svc = PlannerService::with_threads(2);
    let resps = svc.serve_cancellable(&[req.clone(), req], 2, &token);
    assert_eq!(resps.len(), 2);
    assert!(resps.iter().all(|r| r.status == Status::Cancelled), "{:?}", resps[0].status);
}

#[test]
fn request_file_roundtrip_through_serve_validates() {
    // Mirrors the CI smoke: parse a batch file, serve it, emit the
    // response array, re-parse it, and check every plan.
    let file = r#"[
        {"id": "bert-gpipe", "model": "bert", "env": "EnvB", "batch": 16, "max_pp": 2},
        {"id": "bert-1f1b", "model": "bert", "env": "EnvB", "batch": 16,
         "schedule": "1f1b", "max_pp": 2},
        {"id": "galvatron", "model": "bert", "env": "EnvB", "batch": 16,
         "method": "galvatron"}
    ]"#;
    let reqs = PlanRequest::parse_batch(file).expect("parses");
    let svc = PlannerService::with_threads(2);
    let resps = svc.serve(&reqs, 2);
    let text = Json::Arr(resps.iter().map(PlanResponse::to_json).collect()).to_string();
    let parsed = Json::parse(&text).expect("responses parse");
    let items = parsed.as_arr().unwrap();
    assert_eq!(items.len(), 3);
    for (i, item) in items.iter().enumerate() {
        let resp = PlanResponse::from_json(item).expect("response parses");
        assert_eq!(resp.status, Status::Ok, "request {i}");
        let plan = resp.plan.expect("plan present");
        let req = &reqs[i];
        let env = uniap::cluster::ClusterEnv::by_name(&req.env).unwrap();
        let graph = uniap::graph::models::by_name(&req.model).unwrap();
        let profile = uniap::profiling::Profile::analytic(&env, &graph);
        let costs = uniap::cost::cost_modeling_sched(
            &profile,
            &graph,
            plan.pp_size,
            plan.batch,
            plan.num_micro,
            req.schedule,
        );
        let violations = plan.check(&graph, &costs);
        assert!(violations.is_empty(), "request {i}: {violations:?}");
    }
}
