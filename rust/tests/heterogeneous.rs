//! Heterogeneous-cluster battery (ISSUE 10).
//!
//! Pins the two halves of the acceptance criteria end to end:
//!
//! * **Backwards bit-identity** — any homogeneous environment pushed
//!   through the heterogeneous code path (a device table with one
//!   repeated entry) must produce bit-identical cost coefficients and
//!   plans to the legacy path, all the way through the UOP sweep and the
//!   serving cache layer.
//! * **Forward value** — on the mixed V100/TITAN EnvF the planner must
//!   exploit the asymmetry: unequal layer counts on unequal hardware, a
//!   strictly better modeled TPI than a homogeneity-forced plan, stage
//!   memory held to the *smaller* device's budget, and cache fingerprints
//!   that never alias the homogeneous reference.

use uniap::cluster::{ClusterEnv, NodeSpec};
use uniap::cost::{cost_modeling, objective_tpi, stage_memory};
use uniap::graph::models;
use uniap::planner::{chain, uop, PlannerConfig};
use uniap::profiling::Profile;
use uniap::service::{
    workload_fingerprint, PlanRequest, PlannerService, Status,
};

/// `env` with its implicit homogeneity spelled out as a repeated-entry
/// device table — the degenerate heterogeneous description of the same
/// physical cluster.
fn with_repeated_table(env: &ClusterEnv) -> ClusterEnv {
    let mut het = env.clone();
    het.node_table = (0..het.nodes)
        .map(|_| NodeSpec { device: het.device.clone(), gpus: het.gpus_per_node })
        .collect();
    het
}

#[test]
fn repeated_table_uop_sweep_is_bit_identical_to_legacy() {
    // The full Algorithm 1 sweep (cost bases, materialisation, frontier
    // memo, incumbent sharing) must not notice a repeated-entry table.
    let g = models::bert_huge();
    let legacy = ClusterEnv::env_b();
    let het = with_repeated_table(&legacy);
    let cfg = PlannerConfig { threads: 1, ..Default::default() };
    let a = uop(&Profile::analytic(&legacy, &g), &g, 16, &cfg);
    let b = uop(&Profile::analytic(&het, &g), &g, 16, &cfg);
    let (pa, pb) = (a.best.expect("feasible"), b.best.expect("feasible"));
    assert_eq!(pa.pp_size, pb.pp_size);
    assert_eq!(pa.num_micro, pb.num_micro);
    assert_eq!(pa.placement, pb.placement);
    assert_eq!(pa.choice, pb.choice);
    assert_eq!(pa.est_tpi.to_bits(), pb.est_tpi.to_bits(), "TPI must match to the bit");
    // every candidate's logged optimum matches too, not just the winner
    for (la, lb) in a.log.iter().zip(b.log.iter()) {
        assert_eq!((la.pp_size, la.num_micro), (lb.pp_size, lb.num_micro));
        assert_eq!(
            la.tpi.map(f64::to_bits),
            lb.tpi.map(f64::to_bits),
            "candidate pp={} c={} diverged",
            la.pp_size,
            la.num_micro
        );
    }
}

#[test]
fn every_homogeneous_preset_survives_the_repeated_table_path() {
    // Property over the whole preset zoo: repeated-entry coefficients are
    // bit-identical at the cost-matrix level (the solver inputs).
    let g = models::synthetic_chain(6, 5e11, 2e7, 2e6);
    for env in [
        ClusterEnv::env_a(),
        ClusterEnv::env_b(),
        ClusterEnv::env_c(),
        ClusterEnv::env_d(),
        ClusterEnv::env_e(),
    ] {
        let het = with_repeated_table(&env);
        let pl = Profile::analytic(&env, &g);
        let ph = Profile::analytic(&het, &g);
        let n = env.total_devices();
        for pp in [1usize, 2] {
            if n % pp != 0 {
                continue;
            }
            let cl = cost_modeling(&pl, &g, pp, 8, 2);
            let ch = cost_modeling(&ph, &g, pp, 8, 2);
            for u in 0..cl.num_layers() {
                for k in 0..cl.num_strategies() {
                    for stage in 0..pp {
                        assert_eq!(
                            cl.stage_a(u, k, stage).to_bits(),
                            ch.stage_a(u, k, stage).to_bits(),
                            "{}: a[{u}][{k}] stage {stage}",
                            env.name
                        );
                    }
                }
            }
            for stage in 0..pp {
                assert_eq!(
                    cl.stage_limit(stage).to_bits(),
                    ch.stage_limit(stage).to_bits(),
                    "{}: stage {stage} memory budget",
                    env.name
                );
            }
        }
    }
}

#[test]
fn envf_vs_homogeneous_throughput() {
    // EXPERIMENTS.md §PR 10 gate: priced by the true (heterogeneous)
    // objective, the heterogeneity-aware plan strictly beats the plan a
    // homogeneity-forced cost model picks for the same cluster.
    let g = models::synthetic_chain(8, 5e11, 2e7, 2e6);
    let het_env = ClusterEnv::env_f();
    let mut hom_env = het_env.clone();
    hom_env.node_table.clear(); // forced homogeneous: every rank "is" the V100 reference
    let cfg = PlannerConfig::default();
    let het_costs = cost_modeling(&Profile::analytic(&het_env, &g), &g, 2, 8, 2);
    let hom_costs = cost_modeling(&Profile::analytic(&hom_env, &g), &g, 2, 8, 2);
    let het_plan = chain::solve_chain(&g, &het_costs, &cfg).expect("feasible");
    let hom_plan = chain::solve_chain(&g, &hom_costs, &cfg).expect("feasible");
    assert_ne!(
        het_plan.placement, hom_plan.placement,
        "the het-aware split must differ from the balanced homogeneous one"
    );
    let het_tpi = objective_tpi(&g, &het_costs, &het_plan.placement, &het_plan.choice);
    let forced_tpi = objective_tpi(&g, &het_costs, &hom_plan.placement, &hom_plan.choice);
    assert!(
        het_tpi < forced_tpi,
        "het-aware TPI {het_tpi} must strictly beat the homogeneity-forced {forced_tpi}"
    );
}

#[test]
fn envf_plan_respects_the_smaller_titan_memory() {
    // Stage 1's budget is the TITAN's 12 GB, not the reference V100's 32.
    let g = models::bert_huge();
    let env = ClusterEnv::env_f();
    let p = Profile::analytic(&env, &g);
    let costs = cost_modeling(&p, &g, 2, 16, 4);
    assert!(
        costs.stage_limit(1) < costs.stage_limit(0),
        "TITAN stage budget {} must undercut the V100 stage's {}",
        costs.stage_limit(1),
        costs.stage_limit(0)
    );
    if let Some(plan) = chain::solve_chain(&g, &costs, &PlannerConfig::default()) {
        assert!(plan.check(&g, &costs).is_empty(), "{:?}", plan.check(&g, &costs));
        let mem = stage_memory(&g, &costs, &plan.placement, &plan.choice);
        assert!(mem[1] <= costs.stage_limit(1));
    }
}

#[test]
fn device_table_changes_the_workload_fingerprint() {
    let g = models::bert_huge();
    let het = ClusterEnv::env_f();
    let mut hom = het.clone();
    hom.node_table.clear();
    assert_ne!(
        workload_fingerprint(&het, &g),
        workload_fingerprint(&hom, &g),
        "heterogeneous EnvF must never alias its homogeneous reference"
    );

    // a repeated-entry table plans bit-identically, yet it is a distinct
    // cluster description and must key its own cache entries
    let legacy = ClusterEnv::env_b();
    let repeated = with_repeated_table(&legacy);
    assert_ne!(workload_fingerprint(&legacy, &g), workload_fingerprint(&repeated, &g));

    // swapping which node hosts the slow block re-keys caches too
    let mut flipped = het.clone();
    flipped.node_table.swap(0, 1);
    assert_ne!(workload_fingerprint(&het, &g), workload_fingerprint(&flipped, &g));
}

#[test]
fn envd_family_names_resolve_back_to_their_env() {
    // The fingerprint/report names env_d_nodes generates must round-trip
    // through by_name (ISSUE 10 satellite).
    for n in [1usize, 2, 3, 4, 8] {
        let name = format!("EnvD-{n}n");
        let env = ClusterEnv::by_name(&name)
            .unwrap_or_else(|| panic!("{name} must resolve"));
        assert_eq!(env.nodes, n);
        assert_eq!(env.name, name);
        // case variants too
        assert!(ClusterEnv::by_name(&name.to_ascii_lowercase()).is_some());
        assert!(ClusterEnv::by_name(&name.to_ascii_uppercase()).is_some());
    }
}

#[test]
fn inline_cluster_request_matches_named_envf_and_replays_from_cache() {
    let service = PlannerService::new();
    let mut named = PlanRequest::new("named", "bert", "EnvF", 16);
    named.max_pp = Some(2);
    let a = service.plan(&named);
    assert_eq!(a.status, Status::Ok, "{:?}", a.error);
    let plan_a = a.plan.expect("EnvF bert plan");

    // the same cluster sent inline hashes to the same workload, so the
    // second request must replay the cached outcome bit-identically
    let mut inline = PlanRequest::new_cluster("inline", "bert", ClusterEnv::env_f(), 16);
    inline.max_pp = Some(2);
    let before = service.stats().plan_hits;
    let b = service.plan(&inline);
    assert_eq!(b.status, Status::Ok, "{:?}", b.error);
    let plan_b = b.plan.expect("inline cluster plan");
    assert_eq!(plan_a.placement, plan_b.placement);
    assert_eq!(plan_a.choice, plan_b.choice);
    assert_eq!(plan_a.est_tpi.to_bits(), plan_b.est_tpi.to_bits());
    assert!(
        service.stats().plan_hits > before,
        "identical workload content must hit the outcome cache"
    );

    // wire round-trip: the inline request survives JSON exactly
    let back = PlanRequest::parse(&inline.to_json().to_string()).expect("round-trip");
    assert_eq!(back, inline);
}

#[test]
fn request_driven_bad_cluster_is_a_typed_error_not_a_panic() {
    // stage_ranks used to assert!; a request naming a degenerate cluster
    // must come back as an error response (satellite: typed errors).
    let service = PlannerService::new();
    let mut cluster = ClusterEnv::env_f();
    cluster.nodes = 0; // malformed on purpose
    let mut req = PlanRequest::new("bad", "bert", "", 16);
    req.cluster = Some(cluster);
    let resp = service.plan(&req);
    assert_eq!(resp.status, Status::Error);
    assert!(
        resp.error.as_deref().unwrap_or("").contains("cluster"),
        "{:?}",
        resp.error
    );
}
