//! Golden-response fixtures (ISSUE 5): the canonical-JSON responses for
//! `examples/requests.json`, committed under `examples/golden/`, pinned
//! byte-for-byte. Any silent cost-model, planner or serializer drift
//! shows up as a fixture diff instead of slipping into production.
//!
//! ## Regenerating the fixtures
//!
//! ```text
//! UNIAP_BLESS=1 cargo test --test golden_responses
//! git diff examples/golden/   # review the drift, then commit it
//! ```
//!
//! The canonical form zeroes only the wall-clock fields (`timings`,
//! per-candidate `solve_secs`) — see `testing::gen::canonical_response_json`.
//! Everything else, including cache counters, is deterministic for the
//! fixed serve configuration used here (one worker, two sweep threads,
//! requests in file order), so byte equality is the right check.
//!
//! Bootstrap: until the first toolchain-equipped run commits fixtures,
//! missing files downgrade to a loud self-consistency check (two
//! independent serves must agree byte-for-byte) instead of failing, so
//! the suite stays green while still exercising determinism. CI runs
//! the bless mode and `git diff --exit-code examples/golden` to catch
//! drift on every push once fixtures are committed.

use std::path::{Path, PathBuf};

use uniap::service::{PlanRequest, PlannerService, Status};
use uniap::testing::gen::canonical_response_json;

fn repo_path(rel: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join(rel)
}

/// Serve the example request file the way the fixtures are defined:
/// a fresh two-thread service, one worker, file order.
fn serve_examples() -> (Vec<PlanRequest>, Vec<String>) {
    let text = std::fs::read_to_string(repo_path("examples/requests.json"))
        .expect("examples/requests.json must exist");
    let reqs = PlanRequest::parse_batch(&text).expect("example requests parse");
    let svc = PlannerService::with_threads(2);
    let canon = svc
        .serve(&reqs, 1)
        .iter()
        .map(|resp| {
            assert_ne!(resp.status, Status::Error, "{}: {:?}", resp.id, resp.error);
            canonical_response_json(resp)
        })
        .collect();
    (reqs, canon)
}

#[test]
fn example_responses_match_the_committed_goldens_byte_for_byte() {
    let (reqs, canon) = serve_examples();
    assert_eq!(reqs.len(), canon.len());
    let golden_dir = repo_path("examples/golden");
    // value-gated: UNIAP_BLESS=0 (or empty) must NOT silently overwrite
    // the fixtures it was meant to leave alone
    let bless = std::env::var("UNIAP_BLESS").is_ok_and(|v| !v.is_empty() && v != "0");
    if bless {
        std::fs::create_dir_all(&golden_dir).expect("create examples/golden");
    }

    let mut missing: Vec<String> = Vec::new();
    for (req, bytes) in reqs.iter().zip(&canon) {
        assert!(!req.id.is_empty(), "golden fixtures key by request id");
        let path = golden_dir.join(format!("{}.json", req.id));
        if bless {
            std::fs::write(&path, bytes).expect("write golden");
            eprintln!("blessed {}", path.display());
            continue;
        }
        match std::fs::read_to_string(&path) {
            Ok(want) => assert_eq!(
                bytes, &want,
                "response for {:?} drifted from {} — if the change is intended, \
                 regenerate with UNIAP_BLESS=1 cargo test --test golden_responses",
                req.id,
                path.display()
            ),
            Err(_) => missing.push(req.id.clone()),
        }
    }
    if !missing.is_empty() {
        // Bootstrap mode (see module docs): no committed fixture yet.
        // Still pin determinism — an independent second serve must
        // reproduce every byte — and say loudly how to create them.
        eprintln!(
            "NOTE: no golden fixture for {missing:?}; run \
             UNIAP_BLESS=1 cargo test --test golden_responses and commit examples/golden/"
        );
        let (_, again) = serve_examples();
        assert_eq!(canon, again, "two serves of the example file must agree byte-for-byte");
    }
}

#[test]
fn canonical_form_is_reparseable_and_strips_only_clocks() {
    let (_, canon) = serve_examples();
    for bytes in &canon {
        let doc = uniap::util::json::Json::parse(bytes).expect("canonical responses parse");
        let timings = doc.get("timings").expect("timings present");
        for field in ["total_secs", "profile_secs", "solve_secs"] {
            assert_eq!(
                timings.get(field).and_then(uniap::util::json::Json::as_f64),
                Some(0.0),
                "canonical form zeroes {field}"
            );
        }
    }
}
