//! Integration battery for the operator-DAG front-end (ISSUE 7): the
//! linearizer must be a pure function of the DAG's *content* — same
//! virtual layers and same lowered chain regardless of input order,
//! run count, or planner thread count — and every malformed DAG must
//! surface as a typed error through the same service path a healthy
//! request takes, never as a panic.
//!
//! The chain-identity half of the guarantee (a chain-shaped DAG lowers
//! to the *identical* `Graph` and plans bit-identically to the chain
//! front-end) lives in `chain_equivalence.rs` next to the other
//! bit-identity properties.

use uniap::dag::{linearize, OpDag, OpEdge};
use uniap::graph::models;
use uniap::service::{plan_to_json, PlanRequest, PlannerService, Status};
use uniap::testing::{self, gen::random_dag};

/// Fisher–Yates shuffle of `0..n` — the op orders we feed `permuted`.
fn random_perm(rng: &mut testing::Rng, n: usize) -> Vec<usize> {
    let mut perm: Vec<usize> = (0..n).collect();
    for i in (1..n).rev() {
        let j = rng.usize_in(0, i + 1);
        perm.swap(i, j);
    }
    perm
}

fn dag_req(id: &str, dag: OpDag, batch: usize) -> PlanRequest {
    let mut req = PlanRequest::new_dag(id, dag, "EnvB", batch);
    req.max_pp = Some(2);
    req
}

#[test]
fn linearization_is_deterministic_and_order_independent() {
    // Clustering is by longest-path depth and members sort by name, so
    // neither a rerun nor *any* permutation of the op/edge arrays may
    // change a byte of the lowered chain or the report.
    testing::check(
        "dag_linearize_order_independent",
        20,
        |rng| {
            let n = rng.usize_in(2, 10);
            let seed = rng.next_u64();
            (n, seed)
        },
        |&(n, seed)| {
            let mut grng = testing::Rng::new(seed);
            let dag = random_dag(&mut grng, n);
            let (g1, r1) = linearize(&dag).map_err(|e| format!("linearize: {e}"))?;
            let (g2, r2) = linearize(&dag).map_err(|e| format!("re-linearize: {e}"))?;
            if format!("{g1:?}") != format!("{g2:?}") || r1.virtual_layers != r2.virtual_layers {
                return Err("two runs over one DAG disagreed".into());
            }
            for _ in 0..3 {
                let perm = random_perm(&mut grng, n);
                let (gp, rp) = linearize(&dag.permuted(&perm))
                    .map_err(|e| format!("permuted linearize: {e}"))?;
                if format!("{gp:?}") != format!("{g1:?}") {
                    return Err(format!(
                        "lowered chain depends on input order under perm {perm:?}"
                    ));
                }
                if rp.virtual_layers != r1.virtual_layers {
                    return Err(format!("report depends on input order under perm {perm:?}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn dag_plans_are_independent_of_planner_thread_count() {
    let mut rng = testing::Rng::new(11);
    let dag = random_dag(&mut rng, 6);
    let mut want = None;
    for threads in [1usize, 2, 4] {
        let svc = PlannerService::with_threads(threads);
        let resp = svc.plan(&dag_req(&format!("t{threads}"), dag.clone(), 8));
        assert_eq!(resp.status, Status::Ok, "threads={threads}: {:?}", resp.error);
        let bytes = plan_to_json(resp.plan.as_ref().unwrap()).to_string();
        match &want {
            None => want = Some(bytes),
            Some(w) => assert_eq!(&bytes, w, "plan bytes drift at threads={threads}"),
        }
    }
}

#[test]
fn invalid_dags_earn_typed_errors_through_the_service_path() {
    let svc = PlannerService::with_threads(1);

    // a back edge closes a cycle through the diamond
    let mut cyclic = models::diamond();
    cyclic.edges.push(OpEdge { src: 3, dst: 0, shape: Vec::new() });
    let resp = svc.plan(&dag_req("cyclic", cyclic, 8));
    assert_eq!(resp.status, Status::Error);
    let err = resp.error.expect("error body");
    assert!(err.contains("cycle"), "must name the cycle: {err}");

    // two ops, no edges: weakly disconnected
    let mut split = models::diamond();
    split.ops.truncate(2);
    split.edges.clear();
    let resp = svc.plan(&dag_req("split", split, 8));
    assert_eq!(resp.status, Status::Error);
    let err = resp.error.expect("error body");
    assert!(err.contains("disconnected"), "must name the disconnect: {err}");

    // the same service still solves a healthy request afterwards
    let resp = svc.plan(&dag_req("healthy", models::diamond(), 8));
    assert_eq!(resp.status, Status::Ok, "{:?}", resp.error);
}

#[test]
fn bert_as_inline_dag_plans_byte_identically_to_the_chain_model() {
    // The same workload entering through either front-end must leave
    // with the same plan bytes — while the two requests live in
    // disjoint fingerprint domains, so neither replays the other's
    // plan cache entry.
    let svc = PlannerService::with_threads(2);
    let mut chain_req = PlanRequest::new("chain-side", "bert", "EnvB", 16);
    chain_req.max_pp = Some(2);
    let chain_resp = svc.plan(&chain_req);
    assert_eq!(chain_resp.status, Status::Ok, "{:?}", chain_resp.error);

    let dag = OpDag::from_graph(&models::by_name("bert").unwrap());
    let mut dag_side = PlanRequest::new_dag("dag-side", dag, "EnvB", 16);
    dag_side.max_pp = Some(2);
    let dag_resp = svc.plan(&dag_side);
    assert_eq!(dag_resp.status, Status::Ok, "{:?}", dag_resp.error);

    assert_eq!(
        plan_to_json(chain_resp.plan.as_ref().unwrap()).to_string(),
        plan_to_json(dag_resp.plan.as_ref().unwrap()).to_string(),
        "front-ends must agree on every plan byte"
    );
    assert_eq!(
        svc.stats().plan_hits,
        0,
        "domain tags must keep the two plan-cache entries apart"
    );
}
