//! Integration + property tests across planner engines, cost models, and
//! the simulator — on the paper's actual models and environments.

use uniap::baselines::{Baseline, BaselineKind};
use uniap::cluster::ClusterEnv;
use uniap::cost::cost_modeling;
use uniap::graph::models;
use uniap::planner::{chain, uop, PlannerConfig};
use uniap::profiling::Profile;
use uniap::sim::{simulate_plan, SimConfig};
use uniap::testing;

#[test]
fn uniap_plans_all_paper_workloads() {
    // Table 1 rows (EnvA, EnvB, EnvC): every workload must be plannable.
    let cases = vec![
        (models::bert_huge(), ClusterEnv::env_a(), 32usize),
        (models::t5_large(), ClusterEnv::env_a(), 16),
        (models::vit_huge(), ClusterEnv::env_a(), 128),
        (models::swin_huge(), ClusterEnv::env_a(), 128),
        (models::bert_huge(), ClusterEnv::env_b(), 16),
        (models::t5_large_with(16, 16), ClusterEnv::env_b(), 8),
        (models::vit_huge(), ClusterEnv::env_b(), 64),
        (models::swin_huge(), ClusterEnv::env_b(), 32),
        (models::llama_7b(), ClusterEnv::env_c(), 8),
    ];
    for (graph, env, batch) in cases {
        let profile = Profile::analytic(&env, &graph);
        let res = uop(&profile, &graph, batch, &PlannerConfig::default());
        let plan = res
            .best
            .unwrap_or_else(|| panic!("{} on {} B={batch}: SOL×", graph.name, env.name));
        let costs = cost_modeling(&profile, &graph, plan.pp_size, batch, plan.num_micro);
        let violations = plan.check(&graph, &costs);
        assert!(violations.is_empty(), "{} on {}: {violations:?}", graph.name, env.name);
        let sim = simulate_plan(&graph, &profile, &plan, &SimConfig::default());
        assert!(!sim.oom, "{} on {}: plan OOMs in simulation", graph.name, env.name);
        assert!(sim.throughput > 0.0);
    }
}

#[test]
fn miqp_engine_agrees_with_chain_engine_on_random_chains() {
    testing::check(
        "miqp_vs_chain",
        12,
        |rng| {
            let nl = rng.usize_in(4, 8);
            let flops = rng.f64_in(1e11, 2e12);
            let params = rng.f64_in(5e6, 5e7);
            let pp = *rng.pick(&[2usize, 4]);
            let c = *rng.pick(&[2usize, 4]);
            (nl, flops, params, pp, c)
        },
        |&(nl, flops, params, pp, c)| {
            let g = models::synthetic_chain(nl, flops, params, 2e6);
            let profile = Profile::analytic(&ClusterEnv::env_b(), &g);
            let costs = cost_modeling(&profile, &g, pp, 8, c);
            let cfg = PlannerConfig { mem_buckets: 4096, ..Default::default() };
            let a = uniap::miqp::solve_miqp(&g, &costs, &cfg);
            let b = chain::solve_chain(&g, &costs, &cfg);
            match (a, b) {
                (Some(x), Some(y)) => {
                    let rel = (x.est_tpi - y.est_tpi).abs() / y.est_tpi;
                    if rel < 1e-4 {
                        Ok(())
                    } else {
                        Err(format!("tpi mismatch: miqp {} chain {}", x.est_tpi, y.est_tpi))
                    }
                }
                (None, None) => Ok(()),
                (x, y) => Err(format!("feasibility mismatch {:?} {:?}", x.is_some(), y.is_some())),
            }
        },
    );
}

#[test]
fn plans_always_satisfy_formulation_constraints() {
    testing::check(
        "plan_constraints",
        10,
        |rng| {
            let nl = rng.usize_in(6, 14);
            let pp = *rng.pick(&[1usize, 2, 4]);
            let c = *rng.pick(&[2usize, 4, 8]);
            let flops = rng.f64_in(1e11, 3e12);
            (nl, pp, c, flops)
        },
        |&(nl, pp, c, flops)| {
            let g = models::synthetic_chain(nl, flops, 2e7, 2e6);
            let profile = Profile::analytic(&ClusterEnv::env_b(), &g);
            let costs = cost_modeling(&profile, &g, pp, 8, c);
            match chain::solve_chain(&g, &costs, &PlannerConfig::default()) {
                None => Ok(()),
                Some(plan) => {
                    let v = uniap::miqp::formulation::constraint_violations(
                        &g,
                        &costs,
                        &plan.placement,
                        &plan.choice,
                    );
                    if v.is_empty() {
                        // formulation objective must equal the plan's
                        let (tpi, _, _) = uniap::miqp::formulation::objective_from_constraints(
                            &g,
                            &costs,
                            &plan.placement,
                            &plan.choice,
                        );
                        if (tpi - plan.est_tpi).abs() < 1e-9 * tpi.max(1.0) {
                            Ok(())
                        } else {
                            Err(format!("objective mismatch {tpi} vs {}", plan.est_tpi))
                        }
                    } else {
                        Err(format!("{v:?}"))
                    }
                }
            }
        },
    );
}

#[test]
fn uop_optimum_dominates_random_feasible_assignments() {
    // The optimality property, checked empirically: no random feasible
    // assignment beats the UOP plan for the same (pp, c).
    let g = models::synthetic_chain(10, 8e11, 2e7, 2e6);
    let profile = Profile::analytic(&ClusterEnv::env_b(), &g);
    let res = uop(&profile, &g, 8, &PlannerConfig::default());
    let best = res.best.expect("feasible");
    testing::check(
        "uop_dominates",
        200,
        |rng| {
            let pp = best.pp_size;
            // random contiguous placement with pp stages
            let mut cuts: Vec<usize> = (0..pp - 1)
                .map(|_| rng.usize_in(1, g.num_layers()))
                .collect();
            cuts.sort_unstable();
            cuts.dedup();
            let mut placement = vec![0usize; g.num_layers()];
            for (si, &cut) in cuts.iter().enumerate() {
                for u in cut..g.num_layers() {
                    placement[u] = si + 1;
                }
            }
            let costs = cost_modeling(&profile, &g, pp, 8, best.num_micro);
            let choice: Vec<usize> = (0..g.num_layers())
                .map(|_| rng.usize_in(0, costs.num_strategies()))
                .collect();
            (placement, choice)
        },
        |(placement, choice)| {
            let pp = best.pp_size;
            if placement.iter().max().unwrap() + 1 != pp {
                return Ok(()); // dedup collapsed stages — not comparable
            }
            let costs = cost_modeling(&profile, &g, pp, 8, best.num_micro);
            let mem = uniap::cost::stage_memory(&g, &costs, placement, choice);
            if mem.iter().any(|&m| m > costs.mem_limit) {
                return Ok(()); // infeasible sample
            }
            let tpi = uniap::cost::objective_tpi(&g, &costs, placement, choice);
            if tpi >= best.est_tpi * (1.0 - 1e-9) {
                Ok(())
            } else {
                Err(format!("random assignment beat UOP: {tpi} < {}", best.est_tpi))
            }
        },
    );
}

#[test]
fn baselines_produce_simulatable_plans_on_bert_envb() {
    let g = models::bert_huge();
    let profile = Profile::analytic(&ClusterEnv::env_b(), &g);
    let cfg = PlannerConfig::default();
    for kind in [
        BaselineKind::UniAP,
        BaselineKind::Galvatron,
        BaselineKind::Alpa,
        BaselineKind::IntraOnly,
    ] {
        let r = Baseline::run(kind, &profile, &g, 16, &cfg);
        let plan = r.plan.unwrap_or_else(|| panic!("{:?} SOL× unexpectedly", kind));
        let sim = simulate_plan(&g, &profile, &plan, &SimConfig::default());
        assert!(sim.throughput.is_finite() && sim.throughput > 0.0, "{kind:?}");
    }
}

#[test]
fn scalability_throughput_grows_with_nodes() {
    // Figure 4a shape: more nodes + proportional batch → higher throughput.
    let g = models::bert_huge();
    let mut last = 0.0;
    for nodes in [1usize, 2, 4] {
        let env = ClusterEnv::env_d_nodes(nodes);
        let profile = Profile::analytic(&env, &g);
        let res = uop(&profile, &g, 8 * nodes, &PlannerConfig::default());
        let plan = res.best.expect("feasible");
        let sim = simulate_plan(&g, &profile, &plan, &SimConfig { jitter: 0.0, iters: 1, ..Default::default() });
        assert!(
            sim.throughput > last,
            "throughput must grow: {nodes} nodes → {}",
            sim.throughput
        );
        last = sim.throughput;
    }
}
