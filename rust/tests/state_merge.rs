//! The shared-state test battery (ISSUE 5): snapshot merging is a
//! *lawful* union — commutative, associative, idempotent on the emitted
//! bytes — and merged state can never change a plan's bytes, whether it
//! arrives through a merge-order permutation, a sibling generation file
//! in a shared `--state-dir`, or a peer's `sync` export. The fuzz half
//! mutates valid snapshot files byte-by-byte and requires the loader to
//! land in a typed cold start (or a benign load), never a panic.

use std::path::PathBuf;

use uniap::service::{
    plan_to_json, LoadOutcome, PlanRequest, PlanResponse, PlannerService, Snapshot, Status,
};
use uniap::testing::{
    self,
    gen::{canonical_response_json, mutate_bytes, random_snapshot},
};

fn temp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("uniap-state-merge-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn bert_req(id: &str) -> PlanRequest {
    let mut req = PlanRequest::new(id, "bert", "EnvB", 16);
    req.max_pp = Some(2); // keep test sweeps small
    req
}

fn enva_req(id: &str) -> PlanRequest {
    let mut req = PlanRequest::new(id, "bert", "EnvA", 32);
    req.max_pp = Some(2);
    req
}

/// The deterministic bytes of a response: correlation id and cache
/// counters zeroed on top of the shared canonical form (a
/// snapshot-warmed solve legitimately reports hits where a cold one
/// reports misses), everything the *planner* decided — status, plan,
/// candidate log TPIs — byte-exact.
fn solver_bytes(resp: &PlanResponse) -> String {
    let mut canon = resp.clone();
    canon.id = String::new();
    canon.cache = Default::default();
    canonical_response_json(&canon)
}

#[test]
fn merge_is_commutative_associative_and_idempotent() {
    testing::check(
        "merge_laws_on_snapshot_bytes",
        8,
        |rng| rng.next_u64(),
        |&seed| {
            let mut rng = testing::Rng::new(seed);
            let a = random_snapshot(&mut rng);
            let b = random_snapshot(&mut rng);
            let c = random_snapshot(&mut rng);
            let bytes = |s: &Snapshot| s.to_json().to_string();

            let ab = a.clone().merge(b.clone());
            let ba = b.clone().merge(a.clone());
            if bytes(&ab) != bytes(&ba) {
                return Err("merge is not commutative".to_string());
            }
            let ab_c = ab.clone().merge(c.clone());
            let a_bc = a.clone().merge(b.clone().merge(c.clone()));
            if bytes(&ab_c) != bytes(&a_bc) {
                return Err("merge is not associative".to_string());
            }
            if bytes(&a.clone().merge(a.clone())) != bytes(&a) {
                return Err("merge is not idempotent".to_string());
            }
            // and the merged document still validates end to end
            let back = Snapshot::parse(&bytes(&ab_c)).map_err(|e| format!("reparse: {e}"))?;
            if back.to_json().to_string() != bytes(&ab_c) {
                return Err("merged document lost emit∘parse identity".to_string());
            }
            if back.counts() != ab_c.counts() {
                return Err("merged counts drifted across the wire".to_string());
            }
            Ok(())
        },
    );
}

/// The acceptance property: a service preloaded from *any* merge order
/// of real snapshots answers byte-identically to a cold solve.
#[test]
fn any_merge_order_preloaded_yields_cold_solve_bytes() {
    let req = bert_req("probe");
    let cold = PlannerService::with_threads(2).plan(&req);
    assert_eq!(cold.status, Status::Ok);
    let want = solver_bytes(&cold);

    // two writers with overlapping-but-different state: one knows the
    // probe workload, the other a different environment
    let writer_a = PlannerService::with_threads(2);
    assert_eq!(writer_a.plan(&bert_req("warm-a")).status, Status::Ok);
    let snap_a = writer_a.export_snapshot();
    let writer_b = PlannerService::with_threads(2);
    assert_eq!(writer_b.plan(&enva_req("warm-b")).status, Status::Ok);
    let snap_b = writer_b.export_snapshot();

    for (label, merged) in [
        ("a∪b", snap_a.clone().merge(snap_b.clone())),
        ("b∪a", snap_b.clone().merge(snap_a.clone())),
        ("a∪a∪b", snap_a.clone().merge(snap_a.clone()).merge(snap_b.clone())),
    ] {
        let svc = PlannerService::with_threads(2);
        let (new_f, new_b) = svc.merge_snapshot(&merged);
        assert!(new_f > 0 && new_b > 0, "{label}: nothing preloaded");
        let resp = svc.plan(&req);
        assert_eq!(resp.status, Status::Ok, "{label}");
        assert_eq!(solver_bytes(&resp), want, "{label}: merged state changed the bytes");
        assert_eq!(resp.cache.base_misses, 0, "{label}: bases must come from the merge");
        assert!(svc.stats().persisted_frontier_hits > 0, "{label}: frontiers unused");
    }
}

/// Acceptance criterion: a server warmed *purely* from a peer's merged
/// snapshot returns byte-identical responses to its own cold solve —
/// the in-memory half of what the CI multi-process smoke job drives
/// over real sockets.
#[test]
fn peer_snapshot_warms_a_cold_server_to_identical_bytes() {
    let req = bert_req("peer-probe");
    // the peer solved the workload and exports its snapshot (this is
    // exactly what the `sync` frame serves)
    let peer = PlannerService::with_threads(2);
    let peer_resp = peer.plan(&req);
    assert_eq!(peer_resp.status, Status::Ok);
    let exported = peer.export_snapshot();

    // wire round-trip: the sync frame carries the serialized document
    let wired = Snapshot::parse(&exported.to_json().to_string()).expect("export validates");

    let fresh = PlannerService::with_threads(2);
    let (frontiers, bases) = fresh.merge_snapshot(&wired);
    assert!(frontiers > 0 && bases > 0);
    let warmed = fresh.plan(&req);
    assert_eq!(warmed.status, Status::Ok);
    assert_eq!(warmed.cache.base_misses, 0, "fully warm from the peer: {:?}", warmed.cache);
    assert!(fresh.stats().persisted_frontier_hits > 0);

    let cold = PlannerService::with_threads(2).plan(&req);
    assert_eq!(
        solver_bytes(&warmed),
        solver_bytes(&cold),
        "peer-warmed solve must be byte-identical to a cold solve"
    );
    assert_eq!(
        plan_to_json(warmed.plan.as_ref().unwrap()).to_string(),
        plan_to_json(cold.plan.as_ref().unwrap()).to_string(),
    );
}

/// Multi-process serving behind one state dir, in miniature: two tagged
/// writers save into one directory; a third service loads the union and
/// serves both workloads fully warm, byte-identical to cold solves.
#[test]
fn shared_state_dir_converges_to_the_union_of_writers() {
    let dir = temp_dir("union");
    let req_b = bert_req("envb");
    let req_a = enva_req("enva");

    let writer_1 = PlannerService::with_threads(2);
    let cold_b = writer_1.plan(&req_b);
    assert_eq!(cold_b.status, Status::Ok);
    writer_1.save_state_tagged(&dir, "w1").expect("save w1");

    let writer_2 = PlannerService::with_threads(2);
    let cold_a = writer_2.plan(&req_a);
    assert_eq!(cold_a.status, Status::Ok);
    writer_2.save_state_tagged(&dir, "w2").expect("save w2");

    // writer 2's save absorbed writer 1's generation (cooperative
    // warming): it now serves the other workload without a base build
    let cross = writer_2.plan(&bert_req("cross"));
    assert_eq!(cross.status, Status::Ok);
    assert_eq!(cross.cache.base_misses, 0, "{:?}", cross.cache);
    assert_eq!(solver_bytes(&cross), solver_bytes(&cold_b));

    // a restarted third process sees the union through state.json
    let restarted = PlannerService::with_threads(2);
    let LoadOutcome::Loaded { frontiers, bases } = restarted.load_state(&dir) else {
        panic!("union state dir must load");
    };
    assert!(frontiers > 0 && bases > 0);
    for (req, want) in [(&req_b, solver_bytes(&cold_b)), (&req_a, solver_bytes(&cold_a))] {
        let resp = restarted.plan(req);
        assert_eq!(resp.status, Status::Ok);
        assert_eq!(resp.cache.base_misses, 0, "{:?}", resp.cache);
        assert_eq!(solver_bytes(&resp), want, "union state changed plan bytes");
    }
    assert!(restarted.stats().persisted_frontier_hits > 0);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Fuzz corpus over the snapshot file bytes: flip, overwrite, insert,
/// delete, truncate and splice random positions of a valid snapshot —
/// the loader must always return a `LoadOutcome` (typed cold start or a
/// benign load), never panic, and must never report *more* state than
/// the pristine file held.
#[test]
fn mutated_snapshot_files_never_panic_the_loader() {
    let dir = temp_dir("fuzz");
    let svc = PlannerService::with_threads(2);
    assert_eq!(svc.plan(&bert_req("fuzz-seed")).status, Status::Ok);
    let path = svc.save_state(&dir).expect("save");
    let pristine = std::fs::read(&path).expect("read snapshot bytes");
    let (max_f, max_b) = (svc.stats().cached_frontiers, svc.stats().cached_bases);
    // fuzz a single-file directory: the mutation must be the only input
    let fuzz_dir = temp_dir("fuzz-case");
    std::fs::create_dir_all(&fuzz_dir).unwrap();
    let fuzz_path = fuzz_dir.join("state.json");

    testing::check(
        "snapshot_byte_mutations",
        60,
        |rng| {
            let op = rng.usize_in(0, 5);
            let pos = rng.usize_in(0, pristine.len());
            let byte = (rng.next_u32() & 0xff) as u8;
            (op, pos, byte)
        },
        |&(op, pos, byte)| {
            let mut bytes = pristine.clone();
            mutate_bytes(&mut bytes, op, pos, byte);
            std::fs::write(&fuzz_path, &bytes).map_err(|e| e.to_string())?;
            let fresh = PlannerService::with_threads(1);
            // must not panic; a benign mutation (e.g. whitespace-free
            // equivalence) may still load, but never *grow* the state
            match fresh.load_state(&fuzz_dir) {
                LoadOutcome::ColdStart { .. } => Ok(()),
                LoadOutcome::Loaded { frontiers, bases } => {
                    if frontiers <= max_f && bases <= max_b {
                        Ok(())
                    } else {
                        Err(format!(
                            "mutation conjured state: {frontiers}/{bases} vs {max_f}/{max_b}"
                        ))
                    }
                }
            }
        },
    );
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&fuzz_dir);
}

/// A second fuzz pass at the *merge* layer: two valid snapshot texts
/// spliced at random boundaries. Splices either fail validation (typed
/// error) or — when they happen to form a valid document — merge
/// losslessly with a real snapshot.
#[test]
fn spliced_snapshot_documents_fail_closed() {
    let a_svc = PlannerService::with_threads(2);
    assert_eq!(a_svc.plan(&bert_req("splice-a")).status, Status::Ok);
    let a = a_svc.export_snapshot().to_json().to_string().into_bytes();
    let b_svc = PlannerService::with_threads(2);
    assert_eq!(b_svc.plan(&enva_req("splice-b")).status, Status::Ok);
    let b = b_svc.export_snapshot().to_json().to_string().into_bytes();
    let real = a_svc.export_snapshot();

    testing::check(
        "snapshot_splices",
        40,
        |rng| (rng.usize_in(0, a.len()), rng.usize_in(0, b.len())),
        |&(cut_a, cut_b)| {
            let mut spliced = a[..cut_a].to_vec();
            spliced.extend_from_slice(&b[cut_b..]);
            let Ok(text) = String::from_utf8(spliced) else {
                return Ok(()); // not even UTF-8: the reader rejects it earlier
            };
            match Snapshot::parse(&text) {
                Err(_) => Ok(()), // typed rejection — the expected outcome
                Ok(snap) => {
                    // the astronomically rare valid splice must still
                    // merge lawfully
                    let merged = real.clone().merge(snap);
                    Snapshot::parse(&merged.to_json().to_string())
                        .map(|_| ())
                        .map_err(|e| format!("valid splice broke the merge: {e}"))
                }
            }
        },
    );
}
