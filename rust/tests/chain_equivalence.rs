//! Equivalence property tests for the Pareto-sparse chain engine
//! (the ISSUE-1 rewrite): the sparse interval DP must return *bit-identical*
//! plans to the MIQP branch-and-bound on randomized chains, agree with the
//! frozen dense-grid reference wherever quantisation cannot bite, and keep
//! its optimum under incumbent-bounded solves.
//!
//! ISSUE 3 extends the guarantee to the parallel planner core: the
//! row-parallel interval DP and the cross-candidate frontier memo must
//! both leave plans bit-identical to the serial, memo-free path.
//!
//! ISSUE 7 extends it to the operator-DAG front-end: a chain-shaped
//! DAG must linearize to the *identical* `Graph` (every cluster a
//! singleton, every annotation byte preserved) and therefore plan
//! bit-identically to the chain that never went through the DAG IR.

use std::sync::atomic::AtomicU64;

use uniap::cluster::ClusterEnv;
use uniap::cost::cost_modeling;
use uniap::dag::{linearize, OpDag};
use uniap::planner::memo::FrontierMemo;
use uniap::planner::{chain, chain_dense, PlannerConfig};
use uniap::profiling::Profile;
use uniap::testing::{self, gen::random_chain};

#[test]
fn sparse_chain_is_bit_identical_to_miqp_on_random_chains() {
    testing::check(
        "sparse_vs_miqp_bit_identical",
        10,
        |rng| {
            let n = rng.usize_in(4, 8);
            let pp = *rng.pick(&[2usize, 4]);
            let c = *rng.pick(&[2usize, 4]);
            let seed = rng.next_u64();
            (n, pp, c, seed)
        },
        |&(n, pp, c, seed)| {
            let mut grng = testing::Rng::new(seed);
            let g = random_chain(&mut grng, n);
            let profile = Profile::analytic(&ClusterEnv::env_b(), &g);
            let costs = cost_modeling(&profile, &g, pp, 8, c);
            let cfg = PlannerConfig::default();
            let sparse = chain::solve_chain(&g, &costs, &cfg);
            let miqp = uniap::miqp::solve_miqp(&g, &costs, &cfg);
            match (sparse, miqp) {
                (Some(a), Some(b)) => {
                    if a.placement != b.placement {
                        return Err(format!(
                            "placement mismatch: chain {:?} vs miqp {:?}",
                            a.placement, b.placement
                        ));
                    }
                    if a.choice != b.choice {
                        return Err(format!(
                            "choice mismatch: chain {:?} vs miqp {:?}",
                            a.choice, b.choice
                        ));
                    }
                    if a.est_tpi.to_bits() != b.est_tpi.to_bits() {
                        return Err(format!(
                            "est_tpi not bit-identical: {} vs {}",
                            a.est_tpi, b.est_tpi
                        ));
                    }
                    Ok(())
                }
                (None, None) => Ok(()),
                (a, b) => Err(format!(
                    "feasibility mismatch: chain {:?} vs miqp {:?}",
                    a.is_some(),
                    b.is_some()
                )),
            }
        },
    );
}

#[test]
fn sparse_agrees_with_dense_reference_when_memory_is_slack() {
    // With tiny tensors every assignment fits even after the dense grid's
    // round-up, so the frozen legacy engine must find the same optimum.
    testing::check(
        "sparse_vs_dense_slack",
        10,
        |rng| {
            let n = rng.usize_in(4, 9);
            let pp = *rng.pick(&[2usize, 4]);
            let c = *rng.pick(&[2usize, 4]);
            let flops = rng.f64_in(1e11, 2e12);
            (n, pp, c, flops)
        },
        |&(n, pp, c, flops)| {
            let g = uniap::graph::models::synthetic_chain(n, flops, 1e6, 1e6);
            let profile = Profile::analytic(&ClusterEnv::env_b(), &g);
            let costs = cost_modeling(&profile, &g, pp, 8, c);
            let cfg = PlannerConfig::default();
            let sparse = chain::solve_chain(&g, &costs, &cfg);
            let dense = chain_dense::solve_chain_dense(&g, &costs, &cfg);
            match (sparse, dense) {
                (Some(a), Some(b)) => {
                    let rel = (a.est_tpi - b.est_tpi).abs() / b.est_tpi;
                    if rel < 1e-9 {
                        Ok(())
                    } else {
                        Err(format!("tpi mismatch: sparse {} dense {}", a.est_tpi, b.est_tpi))
                    }
                }
                (None, None) => Ok(()),
                (a, b) => Err(format!(
                    "feasibility mismatch: sparse {:?} dense {:?}",
                    a.is_some(),
                    b.is_some()
                )),
            }
        },
    );
}

#[test]
fn row_parallel_and_memoised_solves_are_bit_identical_to_serial() {
    // The tentpole guarantee of the parallel planner core: fanning the
    // per-`l` interval rows across threads and reusing memoised memory
    // frontiers may change *nothing* about the returned plan — same
    // placement, same choices, same objective bits — on randomized
    // heterogeneous chains where ties have probability zero.
    testing::check(
        "row_parallel_memo_bit_identical",
        10,
        |rng| {
            let n = rng.usize_in(4, 10);
            let pp = *rng.pick(&[2usize, 4]);
            let c = *rng.pick(&[2usize, 4]);
            let helpers = *rng.pick(&[1usize, 2, 5]);
            let seed = rng.next_u64();
            (n, pp, c, helpers, seed)
        },
        |&(n, pp, c, helpers, seed)| {
            let mut grng = testing::Rng::new(seed);
            let g = random_chain(&mut grng, n);
            let profile = Profile::analytic(&ClusterEnv::env_b(), &g);
            let costs = cost_modeling(&profile, &g, pp, 8, c);
            let serial_cfg = PlannerConfig { row_helpers: Some(0), ..Default::default() };
            let par_cfg = PlannerConfig { row_helpers: Some(helpers), ..Default::default() };
            let memo = FrontierMemo::new();
            let serial = chain::solve_chain_with(&g, &costs, &serial_cfg, None, None, None);
            let par = chain::solve_chain_with(&g, &costs, &par_cfg, None, None, Some(&memo));
            // a second memoised solve replays the stored frontier
            let warm = chain::solve_chain_with(&g, &costs, &par_cfg, None, None, Some(&memo));
            match (serial, par, warm) {
                (Some(a), Some(b), Some(w)) => {
                    if a.placement != b.placement || a.choice != b.choice {
                        return Err(format!(
                            "plan mismatch: serial {:?}/{:?} vs parallel {:?}/{:?}",
                            a.placement, a.choice, b.placement, b.choice
                        ));
                    }
                    if a.est_tpi.to_bits() != b.est_tpi.to_bits() {
                        return Err(format!(
                            "est_tpi not bit-identical: {} vs {}",
                            a.est_tpi, b.est_tpi
                        ));
                    }
                    if w.est_tpi.to_bits() != a.est_tpi.to_bits() || w.choice != a.choice {
                        return Err("memo-warm solve diverged".to_string());
                    }
                    let (hits, misses) = memo.stats();
                    if (hits, misses) != (1, 1) {
                        return Err(format!("memo not reused: hits {hits} misses {misses}"));
                    }
                    Ok(())
                }
                (None, None, None) => Ok(()),
                (a, b, w) => Err(format!(
                    "feasibility mismatch: serial {:?} parallel {:?} warm {:?}",
                    a.is_some(),
                    b.is_some(),
                    w.is_some()
                )),
            }
        },
    );
}

#[test]
fn chain_as_dag_linearizes_to_identity_and_plans_bit_identically() {
    // The DAG front-end's identity guarantee (ISSUE 7): round-tripping
    // a chain through the operator-DAG IR is a no-op. The lowered graph
    // must match field for field — same names, same type keys, same
    // annotation bits — so the sparse chain engine, fed the same cost
    // matrices, returns the same plan down to the objective bits.
    testing::check(
        "chain_as_dag_identity",
        10,
        |rng| {
            let n = rng.usize_in(4, 9);
            let pp = *rng.pick(&[2usize, 4]);
            let c = *rng.pick(&[2usize, 4]);
            let seed = rng.next_u64();
            (n, pp, c, seed)
        },
        |&(n, pp, c, seed)| {
            let mut grng = testing::Rng::new(seed);
            let g = random_chain(&mut grng, n);
            let (lowered, report) = linearize(&OpDag::from_graph(&g))
                .map_err(|e| format!("linearize failed on a chain: {e}"))?;
            if format!("{lowered:?}") != format!("{g:?}") {
                return Err(format!(
                    "lowering is not the identity:\n  chain {g:?}\n  lowered {lowered:?}"
                ));
            }
            if report.merged_clusters() != 0 || report.skip_edges != 0 {
                return Err(format!(
                    "a chain must produce only singletons: {} merged, {} skips",
                    report.merged_clusters(),
                    report.skip_edges
                ));
            }
            let profile = Profile::analytic(&ClusterEnv::env_b(), &g);
            let costs = cost_modeling(&profile, &g, pp, 8, c);
            let lowered_profile = Profile::analytic(&ClusterEnv::env_b(), &lowered);
            let lowered_costs = cost_modeling(&lowered_profile, &lowered, pp, 8, c);
            let cfg = PlannerConfig::default();
            let direct = chain::solve_chain(&g, &costs, &cfg);
            let via_dag = chain::solve_chain(&lowered, &lowered_costs, &cfg);
            match (direct, via_dag) {
                (Some(a), Some(b)) => {
                    if a.placement != b.placement || a.choice != b.choice {
                        return Err(format!(
                            "plan mismatch: direct {:?}/{:?} vs via-dag {:?}/{:?}",
                            a.placement, a.choice, b.placement, b.choice
                        ));
                    }
                    if a.est_tpi.to_bits() != b.est_tpi.to_bits() {
                        return Err(format!(
                            "est_tpi not bit-identical: {} vs {}",
                            a.est_tpi, b.est_tpi
                        ));
                    }
                    Ok(())
                }
                (None, None) => Ok(()),
                (a, b) => Err(format!(
                    "feasibility mismatch: direct {:?} via-dag {:?}",
                    a.is_some(),
                    b.is_some()
                )),
            }
        },
    );
}

#[test]
fn incumbent_bounded_solves_keep_their_optimum() {
    // Seeding either engine with its own optimum as the sweep incumbent
    // must not change the returned plan (the strict-cut + slack contract
    // behind cross-candidate sharing in the UOP).
    testing::check(
        "incumbent_keeps_optimum",
        8,
        |rng| {
            let n = rng.usize_in(4, 8);
            let pp = *rng.pick(&[2usize, 4]);
            let seed = rng.next_u64();
            (n, pp, seed)
        },
        |&(n, pp, seed)| {
            let mut grng = testing::Rng::new(seed);
            let g = random_chain(&mut grng, n);
            let profile = Profile::analytic(&ClusterEnv::env_b(), &g);
            let costs = cost_modeling(&profile, &g, pp, 8, 4);
            let cfg = PlannerConfig::default();
            let Some(free) = chain::solve_chain(&g, &costs, &cfg) else {
                return Ok(()); // infeasible case — nothing to bound
            };
            let inc = AtomicU64::new(free.est_tpi.to_bits());
            let chain_bounded = chain::solve_chain_bounded(&g, &costs, &cfg, Some(&inc), None)
                .ok_or("chain lost its optimum under its own incumbent")?;
            if chain_bounded.placement != free.placement || chain_bounded.choice != free.choice {
                return Err("bounded chain plan differs from the free plan".into());
            }
            let miqp_bounded = uniap::miqp::solve_miqp_bounded(&g, &costs, &cfg, Some(&inc), None)
                .ok_or("miqp lost its optimum under the incumbent")?;
            if (miqp_bounded.est_tpi - free.est_tpi).abs() > 1e-12 * free.est_tpi {
                return Err(format!(
                    "bounded miqp {} vs free {}",
                    miqp_bounded.est_tpi, free.est_tpi
                ));
            }
            Ok(())
        },
    );
}
