//! Integration tests for the `serve --listen` socket mode (ISSUE 4):
//! the server is driven in-process over real loopback TCP — malformed
//! and oversized frames, mid-solve disconnects, concurrent warm-cache
//! requests, and a kill-and-restart cycle over the persisted state
//! snapshot. Everything must come back as typed responses, never as a
//! panic, and socket-served plans must be byte-identical to direct
//! `PlannerService::plan` calls.
//!
//! ISSUE 5 extends the battery to the shared-state layer: the `sync`
//! frame exports a mergeable snapshot over the wire, randomly mutated
//! NDJSON frames (the fuzz corpus includes the sync frame) always earn
//! a typed reply, and a state dir littered with truncated / spliced /
//! binary-garbage multi-writer generation files still loads whatever
//! validates and serves normally.

use std::io::{BufReader, BufWriter, Write as _};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use uniap::dag::OpEdge;
use uniap::graph::models;
use uniap::service::server::{fetch_snapshot, serve_frame};
use uniap::service::{
    plan_to_json, CancelToken, PlanRequest, PlanResponse, PlannerService, ServerOptions, Snapshot,
    Status,
};
use uniap::testing;
use uniap::testing::harness::{bert_req, round_trip, TestServer};
use uniap::util::json::Json;
use uniap::util::net::{read_frame, write_frame, FrameError};

fn temp_dir(name: &str) -> PathBuf {
    testing::harness::temp_dir("serve", name)
}

#[test]
fn malformed_frames_get_typed_errors_and_the_connection_survives() {
    let mut server =
        TestServer::start(Arc::new(PlannerService::with_threads(2)), ServerOptions::default());
    let (mut reader, mut writer) = server.connect();

    // malformed JSON → typed error, connection stays open
    let resp = round_trip(&mut reader, &mut writer, "this is not json");
    assert_eq!(resp.status, Status::Error);
    assert!(resp.error.unwrap().contains("malformed"));

    // invalid field values → typed error echoing the id
    let resp = round_trip(
        &mut reader,
        &mut writer,
        r#"{"id":"bad","model":"bert","env":"EnvB","batch":16,"deadline_secs":-1}"#,
    );
    assert_eq!(resp.status, Status::Error);
    assert_eq!(resp.id, "bad");

    // unknown model → typed error
    let resp = round_trip(
        &mut reader,
        &mut writer,
        r#"{"id":"ghost","model":"gpt9","env":"EnvB","batch":16}"#,
    );
    assert_eq!(resp.status, Status::Error);
    assert!(resp.error.unwrap().contains("unknown model"));

    // …and the very same connection still serves a real request,
    // byte-identical to the in-process service
    let req = bert_req("after-errors");
    let resp = round_trip(&mut reader, &mut writer, &req.to_json().to_string());
    assert_eq!(resp.status, Status::Ok);
    let direct = PlannerService::with_threads(2).plan(&req);
    assert_eq!(
        plan_to_json(resp.plan.as_ref().unwrap()).to_string(),
        plan_to_json(direct.plan.as_ref().unwrap()).to_string(),
        "socket-served plan must equal the in-process plan"
    );
    server.stop().expect("clean shutdown");
}

#[test]
fn invalid_inline_dag_frames_get_typed_errors_over_the_socket() {
    // ISSUE 7: a request whose inline operator DAG has a cycle must come
    // back as a typed error naming the cycle — through the same framing,
    // validation and dispatch layers a healthy DAG request takes — and
    // leave the connection serving.
    let mut server =
        TestServer::start(Arc::new(PlannerService::with_threads(2)), ServerOptions::default());
    let (mut reader, mut writer) = server.connect();

    let mut cyclic = models::diamond();
    cyclic.edges.push(OpEdge { src: 3, dst: 0, shape: Vec::new() });
    let mut req = PlanRequest::new_dag("cyclic", cyclic, "EnvB", 8);
    req.max_pp = Some(2);
    let resp = round_trip(&mut reader, &mut writer, &req.to_json().to_string());
    assert_eq!(resp.status, Status::Error);
    assert_eq!(resp.id, "cyclic");
    let err = resp.error.expect("error body");
    assert!(err.contains("cycle"), "must name the cycle: {err}");

    // the same connection still plans the healthy version of the DAG,
    // byte-identical to the in-process service
    let mut req = PlanRequest::new_dag("healthy", models::diamond(), "EnvB", 8);
    req.max_pp = Some(2);
    let resp = round_trip(&mut reader, &mut writer, &req.to_json().to_string());
    assert_eq!(resp.status, Status::Ok, "{:?}", resp.error);
    let direct = PlannerService::with_threads(2).plan(&req);
    assert_eq!(
        plan_to_json(resp.plan.as_ref().unwrap()).to_string(),
        plan_to_json(direct.plan.as_ref().unwrap()).to_string(),
        "socket-served DAG plan must equal the in-process plan"
    );
    server.stop().expect("clean shutdown");
}

#[test]
fn oversized_frames_abort_the_connection_with_a_typed_error() {
    let opts = ServerOptions { max_frame_bytes: 512, ..Default::default() };
    let mut server = TestServer::start(Arc::new(PlannerService::with_threads(2)), opts);
    let (mut reader, mut writer) = server.connect();
    let huge = format!("{{\"id\":\"{}\"}}", "x".repeat(4096));
    write_frame(&mut writer, &huge).expect("send");
    let never = || false;
    let line = read_frame(&mut reader, 1 << 20, &never).expect("read").expect("error frame");
    let resp = PlanResponse::parse(&line).expect("typed error");
    assert_eq!(resp.status, Status::Error);
    assert!(resp.error.unwrap().contains("cap"), "names the frame cap");
    // framing is lost → server closes; the next read sees the end of the
    // connection (clean EOF, or a reset if the kernels race the close)
    match read_frame(&mut reader, 1 << 20, &never) {
        Ok(None) | Err(FrameError::Io(_)) => {}
        other => panic!("connection must be closed, got {other:?}"),
    }
    // the server itself is fine: a fresh connection serves
    let (mut r2, mut w2) = server.connect();
    let resp = round_trip(&mut r2, &mut w2, &bert_req("fresh").to_json().to_string());
    assert_eq!(resp.status, Status::Ok);
    server.stop().expect("clean shutdown");
}

#[test]
fn mid_solve_disconnect_does_not_take_the_server_down() {
    let mut server =
        TestServer::start(Arc::new(PlannerService::with_threads(2)), ServerOptions::default());
    {
        // fire a real request and vanish before the response arrives
        let stream = TcpStream::connect(server.addr).unwrap();
        let mut writer = BufWriter::new(stream);
        let frame = bert_req("vanishing").to_json().to_string();
        writer.write_all(frame.as_bytes()).unwrap();
        writer.write_all(b"\n").unwrap();
        writer.flush().unwrap();
        // drop: both halves close while the solve is (likely) in flight
    }
    // the server must keep serving new connections afterwards
    let (mut reader, mut writer) = server.connect();
    let resp = round_trip(&mut reader, &mut writer, &bert_req("survivor").to_json().to_string());
    assert_eq!(resp.status, Status::Ok);
    server.stop().expect("no panic anywhere in the server");
}

#[test]
fn concurrent_connections_serve_byte_identical_warm_plans() {
    let service = Arc::new(PlannerService::with_threads(4));
    // warm the caches once in-process; socket requests must then be
    // pure cache traffic and still byte-identical
    let warm = service.plan(&bert_req("warm-up"));
    assert_eq!(warm.status, Status::Ok);
    let want = plan_to_json(warm.plan.as_ref().unwrap()).to_string();

    let mut server = TestServer::start(service.clone(), ServerOptions::default());
    let addr = server.addr;
    let mut clients = Vec::new();
    for i in 0..4 {
        let want = want.clone();
        clients.push(std::thread::spawn(move || {
            let stream = TcpStream::connect(addr).unwrap();
            stream.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
            let read_half = stream.try_clone().unwrap();
            let mut reader = BufReader::new(read_half);
            let mut writer = BufWriter::new(stream);
            let req = bert_req(&format!("client-{i}"));
            write_frame(&mut writer, &req.to_json().to_string()).unwrap();
            let never = || false;
            let line = read_frame(&mut reader, 1 << 24, &never).unwrap().unwrap();
            let resp = PlanResponse::parse(&line).unwrap();
            assert_eq!(resp.status, Status::Ok);
            assert_eq!(resp.id, format!("client-{i}"), "responses stay per-connection");
            assert_eq!(
                plan_to_json(resp.plan.as_ref().unwrap()).to_string(),
                want,
                "all clients see the same bytes"
            );
        }));
    }
    for c in clients {
        c.join().expect("client");
    }
    let stats = server.service.stats();
    assert!(stats.connections >= 4, "{stats:?}");
    assert!(stats.plan_hits >= 4, "warm requests must replay: {stats:?}");
    server.stop().expect("clean shutdown");
}

#[test]
fn batch_frames_reuse_serve_cancellable_and_keep_request_order() {
    let mut server =
        TestServer::start(Arc::new(PlannerService::with_threads(2)), ServerOptions::default());
    let (mut reader, mut writer) = server.connect();
    let frame = format!(
        "[{},{}]",
        bert_req("first").to_json().to_string(),
        bert_req("second").to_json().to_string()
    );
    write_frame(&mut writer, &frame).unwrap();
    let never = || false;
    let line = read_frame(&mut reader, 1 << 24, &never).unwrap().unwrap();
    let arr = uniap::util::json::Json::parse(&line).unwrap();
    let items = arr.as_arr().expect("batch frame answers with an array");
    assert_eq!(items.len(), 2);
    let first = PlanResponse::from_json(&items[0]).unwrap();
    let second = PlanResponse::from_json(&items[1]).unwrap();
    assert_eq!((first.id.as_str(), second.id.as_str()), ("first", "second"));
    assert!(first.status == Status::Ok && second.status == Status::Ok);
    server.stop().expect("clean shutdown");
}

#[test]
fn health_and_stats_probes_bypass_a_saturated_inflight_cap() {
    // ISSUE 8 satellite: liveness and counter probes must answer even
    // while admission control sheds every plan frame — an operator
    // diagnosing an overloaded fleet node needs exactly those two ops.
    // `max_inflight: 0` is the deterministic saturation: no plan frame
    // can ever hold a permit (the stalled-holder variant lives in the
    // chaos battery, which owns the fault-plan guard discipline).
    let opts = ServerOptions { max_inflight: 0, ..Default::default() };
    let mut server = TestServer::start(Arc::new(PlannerService::with_threads(1)), opts);
    let (mut reader, mut writer) = server.connect();

    // plan frames are shed with a typed busy response...
    let resp = round_trip(&mut reader, &mut writer, &bert_req("shed").to_json().to_string());
    assert_eq!(resp.status, Status::Busy, "{resp:?}");

    // ...while health and stats on the same connection are answered
    let never = || false;
    write_frame(&mut writer, r#"{"op":"health"}"#).unwrap();
    let line = read_frame(&mut reader, 1 << 16, &never).unwrap().unwrap();
    let doc = Json::parse(&line).unwrap();
    assert_eq!(doc.get("status").and_then(Json::as_str), Some("ok"), "{line}");

    write_frame(&mut writer, r#"{"op":"stats"}"#).unwrap();
    let line = read_frame(&mut reader, 1 << 16, &never).unwrap().unwrap();
    let doc = Json::parse(&line).unwrap();
    assert_eq!(doc.get("op").and_then(Json::as_str), Some("stats"));
    assert_eq!(doc.get("status").and_then(Json::as_str), Some("ok"));
    let shed = doc
        .get("stats")
        .and_then(|s| s.get("requests_shed"))
        .and_then(Json::as_usize)
        .expect("stats carries the shed counter");
    assert!(shed >= 1, "the earlier shed plan frame must be counted: {line}");

    // sync is NOT a probe: it moves whole snapshots, so it queues behind
    // admission control like any real work and sheds here
    write_frame(&mut writer, r#"{"op":"sync"}"#).unwrap();
    let line = read_frame(&mut reader, 1 << 16, &never).unwrap().unwrap();
    let resp = PlanResponse::parse(&line).expect("typed busy");
    assert_eq!(resp.status, Status::Busy, "{line}");
    server.stop().expect("clean shutdown");
}

#[test]
fn stats_frame_returns_the_full_counter_document() {
    let mut server =
        TestServer::start(Arc::new(PlannerService::with_threads(2)), ServerOptions::default());
    let (mut reader, mut writer) = server.connect();
    let resp = round_trip(&mut reader, &mut writer, &bert_req("counted").to_json().to_string());
    assert_eq!(resp.status, Status::Ok);

    let never = || false;
    write_frame(&mut writer, r#"{"op":"stats"}"#).unwrap();
    let line = read_frame(&mut reader, 1 << 16, &never).unwrap().unwrap();
    let doc = Json::parse(&line).unwrap();
    assert_eq!(doc.get("status").and_then(Json::as_str), Some("ok"));
    let stats = doc.get("stats").expect("stats object");
    for key in ["requests", "plan_hits", "plan_misses", "forwards", "gossip_rounds"] {
        assert!(stats.get(key).and_then(Json::as_usize).is_some(), "missing {key}: {line}");
    }
    assert_eq!(stats.get("requests").and_then(Json::as_usize), Some(1), "{line}");
    server.stop().expect("clean shutdown");
}

#[test]
fn sync_frame_exports_a_snapshot_that_warms_a_peer_byte_identically() {
    // generation 1: a warm server on machine "A"
    let service = Arc::new(PlannerService::with_threads(2));
    let warm = service.plan(&bert_req("warm-up"));
    assert_eq!(warm.status, Status::Ok);
    let want = plan_to_json(warm.plan.as_ref().unwrap()).to_string();
    let mut server = TestServer::start(service.clone(), ServerOptions::default());

    // raw wire check: one sync frame in, one snapshot document out,
    // and the same connection still serves plan requests afterwards
    let (mut reader, mut writer) = server.connect();
    write_frame(&mut writer, r#"{"op":"sync"}"#).expect("send sync");
    let never = || false;
    let line = read_frame(&mut reader, 1 << 30, &never).expect("read").expect("reply");
    let snap = Snapshot::parse(&line).expect("sync reply must validate as a snapshot");
    let (frontiers, bases) = snap.counts();
    assert!(frontiers > 0 && bases > 0, "warm server must export its caches");
    let resp = round_trip(&mut reader, &mut writer, &bert_req("after-sync").to_json().to_string());
    assert_eq!(resp.status, Status::Ok, "connection survives a sync frame");

    // client helper ("machine B"): pull + merge, then solve fully warm
    let peer = fetch_snapshot(&server.addr.to_string(), 1 << 30, Duration::from_secs(60))
        .expect("fetch_snapshot");
    assert_eq!(peer.counts(), snap.counts());
    let fresh = PlannerService::with_threads(2);
    let (new_f, new_b) = fresh.merge_snapshot(&peer);
    assert_eq!((new_f, new_b), (frontiers, bases));
    let warmed = fresh.plan(&bert_req("via-peer"));
    assert_eq!(warmed.status, Status::Ok);
    assert_eq!(warmed.cache.base_misses, 0, "peer state covers the sweep: {:?}", warmed.cache);
    assert_eq!(
        plan_to_json(warmed.plan.as_ref().unwrap()).to_string(),
        want,
        "a server warmed purely from a peer's snapshot must return identical plan bytes"
    );
    assert!(fresh.stats().persisted_frontier_hits > 0);
    server.stop().expect("clean shutdown");
}

#[test]
fn mutated_frames_always_earn_a_parseable_reply_and_never_panic() {
    // Fuzz the exact per-frame entry point the socket loop runs
    // (serve_frame is shared with the connection handler), over a corpus
    // of valid frames: a request, a batch, the sync op, and an error
    // response masquerading as a request. Mutations that break UTF-8 are
    // repaired lossily — the framing layer's NotUtf8 path has its own
    // test — so every case exercises the JSON/dispatch layers.
    let corpus: Vec<String> = vec![
        bert_req("fuzz").to_json().to_string(),
        format!(
            "[{},{}]",
            bert_req("f1").to_json().to_string(),
            bert_req("f2").to_json().to_string()
        ),
        r#"{"op":"sync"}"#.to_string(),
        r#"{"op":"stats"}"#.to_string(),
        r#"{"op":"gossip","id":"x"}"#.to_string(),
        r#"{"id":"y","status":"error","error":"echo"}"#.to_string(),
    ];
    let svc = PlannerService::with_threads(1);
    let shutdown = CancelToken::new();
    testing::check(
        "ndjson_frame_mutations",
        60,
        |rng| {
            let which = rng.usize_in(0, corpus.len());
            let op = rng.usize_in(0, 5);
            let pos = rng.usize_in(0, corpus[which].len());
            let byte = (rng.next_u32() & 0xff) as u8;
            (which, op, pos, byte)
        },
        |&(which, op, pos, byte)| {
            let mut bytes = corpus[which].clone().into_bytes();
            testing::gen::mutate_bytes(&mut bytes, op, pos, byte);
            let line = String::from_utf8_lossy(&bytes).into_owned();
            let out = serve_frame(&svc, &line, &shutdown, 1);
            // whatever happened, the reply must be one parseable JSON
            // document: a response object, a response array, or (for a
            // sync frame that survived mutation) a snapshot document
            Json::parse(&out).map(|_| ()).map_err(|e| {
                format!("unparseable reply to mutated frame {line:?}: {e}")
            })
        },
    );
}

#[test]
fn truncated_and_spliced_generation_files_never_block_serving() {
    let dir = temp_dir("littered");
    // one good writer
    let writer = Arc::new(PlannerService::with_threads(2));
    let good = writer.plan(&bert_req("good"));
    assert_eq!(good.status, Status::Ok);
    writer.save_state_tagged(&dir, "good").expect("save");
    let good_text = std::fs::read_to_string(dir.join("state.good.json")).unwrap();
    let (want_f, want_b) =
        (writer.stats().cached_frontiers, writer.stats().cached_bases);

    // litter the dir with every multi-writer failure mode: a torn
    // (truncated) generation, two writers' bytes spliced mid-file as if
    // interleaved through a non-atomic write, and binary garbage
    std::fs::write(dir.join("state.torn.json"), &good_text[..good_text.len() / 2]).unwrap();
    let splice = format!(
        "{}{}",
        &good_text[..good_text.len() / 3],
        &good_text[good_text.len() / 2..]
    );
    std::fs::write(dir.join("state.spliced.json"), splice).unwrap();
    std::fs::write(dir.join("state.bin.json"), [0xffu8, 0xfe, 0x00, 0x7b]).unwrap();

    // a restarting server loads exactly the valid state and serves
    let service = Arc::new(PlannerService::with_threads(2));
    match service.load_state(&dir) {
        uniap::service::LoadOutcome::Loaded { frontiers, bases } => {
            assert_eq!((frontiers, bases), (want_f, want_b), "only the valid file counts");
        }
        other => panic!("valid generation must rescue the load, got {other:?}"),
    }
    let opts = ServerOptions { state_dir: Some(dir.clone()), ..Default::default() };
    let mut server = TestServer::start(service, opts);
    let (mut reader, mut writer_io) = server.connect();
    let resp =
        round_trip(&mut reader, &mut writer_io, &bert_req("survivor").to_json().to_string());
    assert_eq!(resp.status, Status::Ok);
    assert_eq!(
        plan_to_json(resp.plan.as_ref().unwrap()).to_string(),
        plan_to_json(good.plan.as_ref().unwrap()).to_string(),
        "litter must not change plan bytes"
    );
    server.stop().expect("clean shutdown despite the littered state dir");
    // the shutdown merge rewrote state.json from whatever validated
    let merged = std::fs::read_to_string(dir.join("state.json")).unwrap();
    let snap = Snapshot::parse(&merged).expect("merged state.json must validate");
    assert_eq!(snap.counts(), (want_f, want_b));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn kill_and_restart_reuses_the_persisted_frontier_memo() {
    let dir = temp_dir("restart");
    let opts = ServerOptions { state_dir: Some(dir.clone()), ..Default::default() };

    // generation 1: serve one request, shut down (writes the snapshot)
    let first_plan;
    {
        let mut server =
            TestServer::start(Arc::new(PlannerService::with_threads(2)), opts.clone());
        let (mut reader, mut writer) = server.connect();
        let resp = round_trip(&mut reader, &mut writer, &bert_req("gen1").to_json().to_string());
        assert_eq!(resp.status, Status::Ok);
        first_plan = plan_to_json(resp.plan.as_ref().unwrap()).to_string();
        let stats = server.service.stats();
        assert!(stats.cached_frontiers > 0 && stats.cached_bases > 0, "{stats:?}");
        server.stop().expect("graceful shutdown writes the snapshot");
        assert!(dir.join("state.json").exists(), "snapshot file must exist");
    }

    // generation 2: fresh process-equivalent — new service, same state dir
    {
        let service = Arc::new(PlannerService::with_threads(2));
        let loaded = service.load_state(&dir);
        let restored = matches!(
            &loaded,
            uniap::service::LoadOutcome::Loaded { frontiers, .. } if *frontiers > 0
        );
        assert!(restored, "{loaded:?}");
        let mut server = TestServer::start(service.clone(), opts.clone());
        let (mut reader, mut writer) = server.connect();
        let resp = round_trip(&mut reader, &mut writer, &bert_req("gen2").to_json().to_string());
        assert_eq!(resp.status, Status::Ok);
        assert_eq!(
            plan_to_json(resp.plan.as_ref().unwrap()).to_string(),
            first_plan,
            "restart must yield bit-identical plans"
        );
        assert_eq!(resp.cache.base_misses, 0, "persisted bases cover the sweep: {:?}", resp.cache);
        let stats = service.stats();
        assert!(stats.persisted_frontiers_loaded > 0, "{stats:?}");
        assert!(stats.persisted_bases_loaded > 0, "{stats:?}");
        assert!(
            stats.persisted_frontier_hits > 0,
            "the warm-start counter is the acceptance gate: {stats:?}"
        );
        server.stop().expect("clean shutdown");
    }
    let _ = std::fs::remove_dir_all(&dir);
}
