//! Integration: the Rust GPipe executor over AOT artifacts is numerically
//! equivalent to the single-program `full_step` reference, and training
//! actually learns.
//!
//! Requires `make artifacts` (the Makefile test target guarantees it).

use uniap::exec::data::Corpus;
use uniap::exec::pipeline::PipelineExecutor;

fn artifacts_dir() -> std::path::PathBuf {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    assert!(
        dir.join("meta.txt").exists(),
        "artifacts missing — run `make artifacts` before `cargo test`"
    );
    dir
}

#[test]
fn pipeline_grads_match_full_step() {
    let mut exec = PipelineExecutor::load(artifacts_dir(), 1e-3).expect("load artifacts");
    let m = exec.meta.clone();
    let mut corpus = Corpus::new(m.vocab, 99);
    // one micro-batch: pipeline path must equal the fused program exactly
    let (toks, tgts) = corpus.next_batch(m.micro_batch, m.seq);
    let (loss_pipe, grads_pipe) = exec.loss_and_grads(&toks, &tgts, 1).expect("pipeline");
    let (loss_full, grads_full) = exec.full_step_reference(&toks, &tgts).expect("full");
    let rel = (loss_pipe - loss_full).abs() / loss_full.abs().max(1e-6);
    assert!(rel < 1e-4, "loss mismatch: pipeline {loss_pipe} vs full {loss_full}");
    assert_eq!(grads_pipe.len(), grads_full.len());
    for (s, (gp, gf)) in grads_pipe.iter().zip(&grads_full).enumerate() {
        assert_eq!(gp.len(), gf.len(), "stage {s} grad length");
        let mut max_abs = 0f32;
        let mut max_err = 0f32;
        for (a, b) in gp.iter().zip(gf) {
            max_abs = max_abs.max(b.abs());
            max_err = max_err.max((a - b).abs());
        }
        assert!(
            max_err <= 1e-4 * max_abs.max(1e-3),
            "stage {s}: max grad err {max_err} (scale {max_abs})"
        );
    }
}

#[test]
fn gradient_accumulation_is_microbatch_mean() {
    // Accumulating over c micro-batches must equal the mean of per-micro
    // gradients (GPipe semantics for a uniformly split mini-batch).
    let mut exec = PipelineExecutor::load(artifacts_dir(), 1e-3).expect("load artifacts");
    let m = exec.meta.clone();
    let mut corpus = Corpus::new(m.vocab, 123);
    let (toks, tgts) = corpus.next_batch(m.micro_batch * 2, m.seq);
    let per = m.micro_batch * m.seq;
    let (loss_acc, grads_acc) = exec.loss_and_grads(&toks, &tgts, 2).expect("acc");
    let (l1, g1) = exec.loss_and_grads(&toks[..per], &tgts[..per], 1).expect("mb1");
    let (l2, g2) = exec.loss_and_grads(&toks[per..], &tgts[per..], 1).expect("mb2");
    assert!((loss_acc - 0.5 * (l1 + l2)).abs() < 1e-5);
    for s in 0..grads_acc.len() {
        for i in (0..grads_acc[s].len()).step_by(97) {
            let want = 0.5 * (g1[s][i] + g2[s][i]);
            assert!(
                (grads_acc[s][i] - want).abs() < 1e-5 + 1e-4 * want.abs(),
                "stage {s} index {i}: {} vs {}",
                grads_acc[s][i],
                want
            );
        }
    }
}

#[test]
fn training_reduces_loss_on_structured_corpus() {
    let mut exec = PipelineExecutor::load(artifacts_dir(), 3e-3).expect("load artifacts");
    let m = exec.meta.clone();
    let mut corpus = Corpus::new(m.vocab, 42);
    let uniform = (m.vocab as f32).ln();
    let mut first = 0.0f32;
    let mut last = 0.0f32;
    let steps = 30;
    for step in 0..steps {
        let (toks, tgts) = corpus.next_batch(m.micro_batch * 2, m.seq);
        let stats = exec.train_step(&toks, &tgts, 2).expect("step");
        if step == 0 {
            first = stats.loss;
        }
        last = stats.loss;
    }
    assert!(first < uniform * 1.05, "initial loss should start near ln(V)={uniform}: {first}");
    assert!(
        last < first - 0.08,
        "loss must decrease over {steps} steps: {first} → {last}"
    );
}
