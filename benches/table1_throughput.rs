//! Table 1 (upper half): training throughput of Galvatron / Alpa / UniAP
//! on EnvA, EnvB, EnvC across the five models. Absolute samples/s come
//! from the discrete-event simulator (our testbed); the paper's *shape* —
//! who wins, OOM/SOL patterns, speedup ranges — is what reproduces.
//!
//! Run: `cargo bench --bench table1_throughput`

use uniap::baselines::{Baseline, BaselineKind};
use uniap::cluster::ClusterEnv;
use uniap::graph::models;
use uniap::planner::PlannerConfig;
use uniap::profiling::Profile;
use uniap::report::Table;
use uniap::sim::{simulate_plan, SimConfig};

fn cell(
    kind: BaselineKind,
    profile: &Profile,
    graph: &uniap::graph::Graph,
    batch: usize,
    cfg: &PlannerConfig,
) -> (String, Option<f64>) {
    let r = Baseline::run(kind, profile, graph, batch, cfg);
    match r.plan {
        None => ("SOL×".to_string(), None),
        Some(plan) => {
            let sim = simulate_plan(graph, profile, &plan, &SimConfig::default());
            if sim.oom {
                ("CUDA×".to_string(), None)
            } else {
                (
                    uniap::metrics::pm(sim.throughput, sim.throughput_std, 2),
                    Some(sim.throughput),
                )
            }
        }
    }
}

fn main() {
    let cfg = PlannerConfig::default();
    let workloads: Vec<(ClusterEnv, &str, usize)> = vec![
        (ClusterEnv::env_a(), "bert", 32),
        (ClusterEnv::env_a(), "t5", 16),
        (ClusterEnv::env_a(), "vit", 128),
        (ClusterEnv::env_a(), "swin", 128),
        (ClusterEnv::env_b(), "bert", 16),
        (ClusterEnv::env_b(), "t5-16", 8),
        (ClusterEnv::env_b(), "vit", 64),
        (ClusterEnv::env_b(), "swin", 32),
        (ClusterEnv::env_c(), "llama-7b", 8),
    ];
    println!("# Table 1 — training throughput (samples/s, simulated testbed)\n");
    let mut table = Table::new(&[
        "env", "model", "Galvatron", "Alpa", "UniAP", "min speedup", "max speedup",
    ]);
    for (env, name, batch) in workloads {
        let graph = models::by_name(name).unwrap();
        let profile = Profile::analytic(&env, &graph);
        let (gal_s, gal) = cell(BaselineKind::Galvatron, &profile, &graph, batch, &cfg);
        let (alp_s, alp) = cell(BaselineKind::Alpa, &profile, &graph, batch, &cfg);
        let (uni_s, uni) = cell(BaselineKind::UniAP, &profile, &graph, batch, &cfg);
        let speedups: Vec<f64> = [gal, alp]
            .iter()
            .flatten()
            .map(|b| uni.unwrap_or(0.0) / b)
            .collect();
        let (mn, mx) = if speedups.is_empty() || uni.is_none() {
            ("N/A".to_string(), "N/A".to_string())
        } else {
            (
                format!("{:.2}", speedups.iter().cloned().fold(f64::INFINITY, f64::min)),
                format!("{:.2}", speedups.iter().cloned().fold(0.0, f64::max)),
            )
        };
        table.row(vec![env.name.clone(), graph.name.clone(), gal_s, alp_s, uni_s, mn, mx]);
    }
    print!("{}", table.to_markdown());
    println!("\npaper shape: UniAP ≥ both baselines everywhere; up to 3.80× on EnvC Llama.");
}
