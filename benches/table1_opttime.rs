//! Table 1 (lower half): strategy optimization time per method.
//!
//! Caveat recorded in EXPERIMENTS.md: the paper's baselines are Python
//! implementations whose optimization cost is dominated by on-hardware
//! profiling and single-threaded DP; our re-implementations are all Rust
//! over an analytic profile, so *absolute* times shrink for everyone and
//! the Galvatron gap narrows. The algorithmic shape that does transfer:
//! Alpa's O(V²) per-interval intra-op solves cost the most, and UniAP
//! stays in seconds.
//!
//! Run: `cargo bench --bench table1_opttime`
//!
//! Runs through one shared [`PlannerService`] — the three methods of each
//! workload reuse the cached profile, exactly like repeated production
//! requests would.

use uniap::baselines::BaselineKind;
use uniap::report::Table;
use uniap::service::{PlanRequest, PlannerService};

fn main() {
    let workloads: Vec<(&str, &str, usize)> = vec![
        ("EnvA", "bert", 32),
        ("EnvA", "t5", 16),
        ("EnvA", "vit", 128),
        ("EnvA", "swin", 128),
        ("EnvB", "bert", 16),
        ("EnvB", "t5-16", 8),
        ("EnvB", "vit", 64),
        ("EnvB", "swin", 32),
        ("EnvC", "llama-7b", 8),
    ];
    let service = PlannerService::new();
    println!("# Table 1 — strategy optimization time\n");
    let mut table = Table::new(&["env", "model", "Galvatron", "Alpa", "UniAP", "speedup vs worst"]);
    for (env, model, batch) in workloads {
        let mut secs = Vec::new();
        for kind in [BaselineKind::Galvatron, BaselineKind::Alpa, BaselineKind::UniAP] {
            let mut req =
                PlanRequest::new(&format!("{env}/{model}/{}", kind.key()), model, env, batch);
            req.method = kind;
            let resp = service.plan(&req);
            secs.push(resp.timings.solve_secs);
        }
        let worst = secs[0].max(secs[1]);
        table.row(vec![
            env.to_string(),
            model.to_string(),
            uniap::util::fmt_secs(secs[0]),
            uniap::util::fmt_secs(secs[1]),
            uniap::util::fmt_secs(secs[2]),
            format!("{:.1}×", worst / secs[2]),
        ]);
    }
    print!("{}", table.to_markdown());
    let stats = service.stats();
    println!(
        "\nservice caches: {} profile hits / {} misses, {} cost-base hits / {} misses",
        stats.profile_hits, stats.profile_misses, stats.base_hits, stats.base_misses
    );
}
