//! Table 1 (lower half): strategy optimization time per method.
//!
//! Caveat recorded in EXPERIMENTS.md: the paper's baselines are Python
//! implementations whose optimization cost is dominated by on-hardware
//! profiling and single-threaded DP; our re-implementations are all Rust
//! over an analytic profile, so *absolute* times shrink for everyone and
//! the Galvatron gap narrows. The algorithmic shape that does transfer:
//! Alpa's O(V²) per-interval intra-op solves cost the most, and UniAP
//! stays in seconds.
//!
//! Run: `cargo bench --bench table1_opttime`

use uniap::baselines::{Baseline, BaselineKind};
use uniap::cluster::ClusterEnv;
use uniap::graph::models;
use uniap::planner::PlannerConfig;
use uniap::profiling::Profile;
use uniap::report::Table;

fn main() {
    let cfg = PlannerConfig::default();
    let workloads: Vec<(ClusterEnv, &str, usize)> = vec![
        (ClusterEnv::env_a(), "bert", 32),
        (ClusterEnv::env_a(), "t5", 16),
        (ClusterEnv::env_a(), "vit", 128),
        (ClusterEnv::env_a(), "swin", 128),
        (ClusterEnv::env_b(), "bert", 16),
        (ClusterEnv::env_b(), "t5-16", 8),
        (ClusterEnv::env_b(), "vit", 64),
        (ClusterEnv::env_b(), "swin", 32),
        (ClusterEnv::env_c(), "llama-7b", 8),
    ];
    println!("# Table 1 — strategy optimization time\n");
    let mut table = Table::new(&["env", "model", "Galvatron", "Alpa", "UniAP", "speedup vs worst"]);
    for (env, name, batch) in workloads {
        let graph = models::by_name(name).unwrap();
        let profile = Profile::analytic(&env, &graph);
        let mut secs = Vec::new();
        for kind in [BaselineKind::Galvatron, BaselineKind::Alpa, BaselineKind::UniAP] {
            let r = Baseline::run(kind, &profile, &graph, batch, &cfg);
            secs.push(r.opt_secs);
        }
        let worst = secs[0].max(secs[1]);
        table.row(vec![
            env.name.clone(),
            graph.name.clone(),
            uniap::util::fmt_secs(secs[0]),
            uniap::util::fmt_secs(secs[1]),
            uniap::util::fmt_secs(secs[2]),
            format!("{:.1}×", worst / secs[2]),
        ]);
    }
    print!("{}", table.to_markdown());
}
