//! §4.2 estimation accuracy: relative estimation error (eq. 9) of each
//! planner's own throughput estimate against the simulated "actual", over
//! the EnvA and EnvB optimal strategies — the paper reports average REE
//! 3.59% for UniAP vs 11.17% for Galvatron.
//!
//! Run: `cargo bench --bench ree_estimation`

use uniap::baselines::{Baseline, BaselineKind};
use uniap::cluster::ClusterEnv;
use uniap::graph::models;
use uniap::planner::PlannerConfig;
use uniap::profiling::Profile;
use uniap::report::Table;
use uniap::sim::{simulate_plan, SimConfig};

fn main() {
    let cfg = PlannerConfig::default();
    let quiet = SimConfig { jitter: 0.0, iters: 1, ..Default::default() };
    let workloads: Vec<(ClusterEnv, &str, usize)> = vec![
        (ClusterEnv::env_a(), "bert", 32),
        (ClusterEnv::env_a(), "t5", 16),
        (ClusterEnv::env_a(), "vit", 128),
        (ClusterEnv::env_a(), "swin", 128),
        (ClusterEnv::env_b(), "bert", 16),
        (ClusterEnv::env_b(), "t5-16", 8),
        (ClusterEnv::env_b(), "vit", 64),
        (ClusterEnv::env_b(), "swin", 32),
    ];
    println!("# §4.2 — relative estimation error of planner estimates\n");
    let mut table = Table::new(&["env", "model", "UniAP REE %", "Galvatron REE %"]);
    let mut uni_all = Vec::new();
    let mut gal_all = Vec::new();
    for (env, name, batch) in workloads {
        let graph = models::by_name(name).unwrap();
        let profile = Profile::analytic(&env, &graph);
        let mut cells = Vec::new();
        for kind in [BaselineKind::UniAP, BaselineKind::Galvatron] {
            let r = Baseline::run(kind, &profile, &graph, batch, &cfg);
            let cell = match r.plan {
                None => "SOL×".to_string(),
                Some(plan) => {
                    let sim = simulate_plan(&graph, &profile, &plan, &quiet);
                    if sim.oom {
                        "CUDA×".to_string()
                    } else {
                        let e = uniap::metrics::ree(sim.throughput, plan.est_throughput());
                        match kind {
                            BaselineKind::UniAP => uni_all.push(e),
                            _ => gal_all.push(e),
                        }
                        format!("{:.2}", 100.0 * e)
                    }
                }
            };
            cells.push(cell);
        }
        table.row(vec![env.name.clone(), graph.name.clone(), cells[0].clone(), cells[1].clone()]);
    }
    print!("{}", table.to_markdown());
    println!(
        "\naverage REE — UniAP: {:.2}% (paper 3.59%), Galvatron: {:.2}% (paper 11.17%)",
        100.0 * uniap::util::mean(&uni_all),
        100.0 * uniap::util::mean(&gal_all)
    );
}
