//! Table 4 (Appendix G): scaling to a 32-DCU cloud cluster — UniAP vs the
//! exhaustive Megatron protocol and DeepSpeed ZeRO-3 on Llama-7B/13B.
//! Megatron's "optimization time" is the simulated cost of test-running
//! every grid candidate for 60 iterations (the paper's measurement
//! protocol); DeepSpeed fails to launch because 8 and 4 don't divide 32.
//!
//! Run: `cargo bench --bench table4_enve`

use uniap::baselines::{megatron, Baseline, BaselineKind};
use uniap::cluster::ClusterEnv;
use uniap::graph::models;
use uniap::planner::PlannerConfig;
use uniap::profiling::Profile;
use uniap::report::Table;
use uniap::sim::{simulate_plan, SimConfig};

fn main() {
    let cfg = PlannerConfig::default();
    let env = ClusterEnv::env_e();
    println!("# Table 4 — EnvE (8 nodes × 4 DCU), Llama models\n");
    let mut table = Table::new(&[
        "model", "Megatron thr", "DeepSpeed thr", "UniAP thr", "Megatron opt", "DeepSpeed opt", "UniAP opt",
    ]);
    for (name, batch) in [("llama-7b", 8usize), ("llama-13b", 4)] {
        let graph = models::by_name(name).unwrap();
        let profile = Profile::analytic(&env, &graph);

        let grid = megatron::run(&profile, &graph, batch, &cfg);
        let mega_thr = grid
            .result
            .plan
            .as_ref()
            .map(|p| {
                let sim = simulate_plan(&graph, &profile, p, &SimConfig::default());
                uniap::metrics::pm(sim.throughput, sim.throughput_std, 2)
            })
            .unwrap_or_else(|| "SOL×".into());
        let mega_opt = uniap::util::fmt_secs(grid.simulated_search_secs);

        let ds = Baseline::run(BaselineKind::DeepSpeedZero3, &profile, &graph, batch, &cfg);
        let ds_cell = ds.plan.map(|_| "ok".to_string()).unwrap_or_else(|| "SOL×".into());

        let uni = Baseline::run(BaselineKind::UniAP, &profile, &graph, batch, &cfg);
        let uni_opt = uniap::util::fmt_secs(uni.opt_secs);
        let uni_thr = uni
            .plan
            .map(|p| {
                let sim = simulate_plan(&graph, &profile, &p, &SimConfig::default());
                uniap::metrics::pm(sim.throughput, sim.throughput_std, 2)
            })
            .unwrap_or_else(|| "SOL×".into());

        table.row(vec![
            graph.name.clone(),
            mega_thr,
            ds_cell,
            uni_thr,
            mega_opt,
            "SOL×".into(),
            uni_opt,
        ]);
    }
    print!("{}", table.to_markdown());
    println!("\npaper shape: UniAP matches the exhaustive-search throughput while its");
    println!("optimization is orders of magnitude cheaper; DeepSpeed cannot launch.");
}
