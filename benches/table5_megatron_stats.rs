//! Table 5 (Appendix G): statistics over Megatron's candidate parallel
//! strategies on EnvE — what a user faces without an optimizer: top-1 vs
//! top-2 vs median vs slowest throughput, and how many candidates are
//! outright infeasible.
//!
//! Run: `cargo bench --bench table5_megatron_stats`

use uniap::baselines::megatron;
use uniap::cluster::ClusterEnv;
use uniap::graph::models;
use uniap::planner::PlannerConfig;
use uniap::profiling::Profile;
use uniap::report::Table;

fn main() {
    let cfg = PlannerConfig::default();
    let env = ClusterEnv::env_e();
    println!("# Table 5 — Megatron candidate statistics (EnvE)\n");
    let mut table = Table::new(&[
        "model", "batch", "top-1", "top-2", "slowest", "median", "#infeasible", "#candidate",
    ]);
    for (name, batch) in [("llama-7b", 8usize), ("llama-13b", 4)] {
        let graph = models::by_name(name).unwrap();
        let profile = Profile::analytic(&env, &graph);
        let grid = megatron::run(&profile, &graph, batch, &cfg);
        match megatron::stats(&grid) {
            Some(s) => {
                table.row(vec![
                    graph.name.clone(),
                    batch.to_string(),
                    format!("{:.2}", s.top1),
                    format!("{:.2}", s.top2),
                    format!("{:.2}", s.slowest),
                    format!("{:.2}", s.median),
                    s.infeasible.to_string(),
                    s.total.to_string(),
                ]);
            }
            None => {
                table.row(vec![
                    graph.name.clone(),
                    batch.to_string(),
                    "—".into(),
                    "—".into(),
                    "—".into(),
                    "—".into(),
                    grid.candidates.len().to_string(),
                    grid.candidates.len().to_string(),
                ]);
            }
        }
    }
    print!("{}", table.to_markdown());
    println!("\npaper shape: most candidates infeasible; picking blind sacrifices");
    println!("throughput (top-1 ≫ median), motivating automatic optimization.");
}
