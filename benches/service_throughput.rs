//! Planner-service throughput: cold-cache vs warm-cache request latency.
//!
//! Three rows on BERT-Huge/EnvB/B=16 (the Table 1 workload the other
//! benches use):
//!
//! 1. **cold** — a fresh `PlannerService` per request: builds the profile,
//!    every factored `CostBase`, and solves the full sweep (the old
//!    one-shot `planner::uop` cost, plus negligible service overhead);
//! 2. **warm, schedule variant** — same service, same `(env, model,
//!    batch)`, different pipeline schedule: the outcome cache misses but
//!    every `CostBase` is reused, so only the solves run;
//! 3. **warm, strict repeat** — the completed-outcome cache replays the
//!    stored plan without solving.
//!
//! The acceptance gate for the service PR is the cold/warm ratio on the
//! repeated request: **≥ 5×** (note `service_warm_speedup`). The bench
//! also asserts the byte-identity guarantee: warm responses carry plans
//! whose canonical JSON equals the cold solve's.
//!
//! ISSUE 3 adds the **batch-generic base** row: a request for a *new*
//! mini-batch on a known workload must reuse every `(fp, pp)` cost base
//! (the cache key lost its batch dimension) — tracked under
//! `warm_new_batch_base_hits`.
//!
//! ISSUE 4 adds the **socket** row: the same warm request served through
//! `serve --listen` over loopback TCP (`service_socket_warm` — the
//! framing + scheduling overhead on top of the in-process warm path),
//! with the byte-identity of socket-served plans asserted against the
//! in-process responses.
//!
//! ISSUE 5 adds the **warm-via-peer** row: a *fresh* service that first
//! merges a peer's exported snapshot (the `sync` frame payload — parse,
//! validate, merge included in the measured time) and then solves. The
//! delta to the cold row is what cross-machine state sync buys a
//! just-booted server; `peer_warm_speedup` records the ratio.
//!
//! ISSUE 6 adds the **load-shed** row: a zero-slot server answering
//! `busy` over the same loopback path. Shedding must be cheaper than
//! serving (`shed_latency_vs_warm_socket` > 1) or admission control
//! would protect nothing.
//!
//! ISSUE 7 adds the **DAG front-end** rows: linearizing the UNet
//! operator DAG into virtual layers, and a cold end-to-end DAG solve.
//! The gate is `dag_linearize_overhead` ≤ 0.05 — linearization must
//! stay under 5% of a cold chain solve, or the front-end would tax
//! every branching request noticeably.
//!
//! Run: `cargo bench --bench service_throughput`
//! CI smoke: `UNIAP_BENCH_SMOKE=1` shrinks rows to single unwarmed
//! samples.
//! Writes `BENCH_service_throughput.json` (schema `uniap-bench-v1`).

use std::io::{BufReader, BufWriter};
use std::net::TcpStream;
use std::sync::Arc;

use uniap::cost::Schedule;
use uniap::dag::linearize;
use uniap::graph::models;
use uniap::report::bench::{section, BenchReport};
use uniap::service::{
    plan_to_json, CancelToken, PlanRequest, PlanResponse, PlannerService, Server, ServerOptions,
    Snapshot, Status,
};
use uniap::util::net::{read_frame, write_frame};

fn main() {
    let smoke = std::env::var("UNIAP_BENCH_SMOKE").is_ok();
    let w = |n: usize| if smoke { 0 } else { n };
    let s = |n: usize| if smoke { 1 } else { n };

    let mut rep = BenchReport::new("service_throughput");
    rep.note("model", "BERT-Huge");
    rep.note("env", "EnvB");
    rep.note("batch", 16usize);
    if smoke {
        rep.note("smoke", true);
    }

    let req = PlanRequest::new("bench", "bert", "EnvB", 16);
    let mut variant = req.clone();
    variant.schedule = Schedule::OneF1B;

    section("planner service: cold vs warm requests");
    rep.bench("service cold (fresh caches per request)", w(1), s(5), || {
        let svc = PlannerService::new();
        std::hint::black_box(svc.plan(&req));
    });

    let svc = Arc::new(PlannerService::new());
    let cold = svc.plan(&req);
    assert_eq!(cold.status, Status::Ok, "workload must be plannable");
    let cold_variant = PlannerService::new().plan(&variant);

    rep.bench("service warm (same batch, different schedule)", w(1), s(5), || {
        std::hint::black_box(svc.plan(&variant));
    });
    rep.bench("service warm (strict repeat)", w(1), s(10), || {
        std::hint::black_box(svc.plan(&req));
    });

    // batch-generic bases: a brand-new mini-batch misses the outcome
    // cache but rebuilds no cost base at all
    let mut new_batch = req.clone();
    new_batch.id = "b8".into();
    new_batch.batch = 8; // strictly less memory than the B=16 baseline
    let warm_b8 = svc.plan(&new_batch);
    assert_eq!(warm_b8.status, Status::Ok);
    assert_eq!(warm_b8.cache.base_misses, 0, "bases must be batch-generic");
    assert!(warm_b8.cache.base_hits > 0);
    rep.note("warm_new_batch_base_hits", warm_b8.cache.base_hits);
    rep.bench("service warm (new batch B=8, shared bases)", w(1), s(5), || {
        std::hint::black_box(svc.plan(&new_batch));
    });

    // --- warm via a peer's merged snapshot (ISSUE 5) ---------------------
    // What `serve --sync-from <peer>` buys a just-booted server: a fresh
    // service merges the peer's exported snapshot (parse + validation +
    // merge measured too) and solves with every cost base and frontier
    // already resident. Only the profile and the outcome cache rebuild.
    section("shared state: warm via peer snapshot");
    let peer_text = svc.export_snapshot().to_json().to_string();
    rep.note("peer_snapshot_bytes", peer_text.len());
    let via_peer = {
        let warmed = PlannerService::new();
        let wired = Snapshot::parse(&peer_text).expect("exported snapshot validates");
        let (frontiers, bases) = warmed.merge_snapshot(&wired);
        rep.note("peer_frontiers_merged", frontiers);
        rep.note("peer_bases_merged", bases);
        let resp = warmed.plan(&req);
        assert_eq!(resp.status, Status::Ok);
        assert_eq!(resp.cache.base_misses, 0, "peer snapshot must cover the sweep");
        assert!(warmed.stats().persisted_frontier_hits > 0, "frontiers must be reused");
        resp
    };
    let identical_peer = plan_to_json(via_peer.plan.as_ref().unwrap()).to_string()
        == plan_to_json(cold.plan.as_ref().unwrap()).to_string();
    assert!(identical_peer, "peer-warmed plan differs from the cold solve");
    rep.note("peer_warm_plan_byte_identical", identical_peer);
    rep.bench("service warm via peer snapshot (fresh service per request)", w(1), s(5), || {
        let warmed = PlannerService::new();
        let wired = Snapshot::parse(&peer_text).expect("exported snapshot validates");
        warmed.merge_snapshot(&wired);
        std::hint::black_box(warmed.plan(&req));
    });
    if let Some(speedup) = rep.speedup(
        "service cold (fresh caches per request)",
        "service warm via peer snapshot (fresh service per request)",
    ) {
        println!("warm-via-peer speedup (incl. snapshot parse + merge): {speedup:.2}×");
        rep.note("peer_warm_speedup", speedup);
    }

    // byte-identity guarantee (the other half of the acceptance gate)
    let warm = svc.plan(&req);
    let warm_variant = svc.plan(&variant);
    let identical_repeat = plan_to_json(warm.plan.as_ref().unwrap()).to_string()
        == plan_to_json(cold.plan.as_ref().unwrap()).to_string();
    let identical_variant = plan_to_json(warm_variant.plan.as_ref().unwrap()).to_string()
        == plan_to_json(cold_variant.plan.as_ref().unwrap()).to_string();
    assert!(identical_repeat, "warm repeat plan differs from cold solve");
    assert!(identical_variant, "warm schedule-variant plan differs from cold solve");
    rep.note("warm_repeat_plan_byte_identical", identical_repeat);
    rep.note("warm_variant_plan_byte_identical", identical_variant);

    let stats = svc.stats();
    rep.note("base_cache_hits", stats.base_hits);
    rep.note("plan_cache_hits", stats.plan_hits);
    rep.note("frontier_cache_hits", stats.frontier_hits);
    rep.note("outcome_evictions", stats.outcome_evictions);

    if let Some(speedup) = rep.speedup(
        "service cold (fresh caches per request)",
        "service warm (strict repeat)",
    ) {
        println!("\nwarm-repeat speedup (BERT-Huge/EnvB/B=16): {speedup:.1}×");
        rep.note("service_warm_speedup", speedup);
        rep.note("acceptance_target_speedup", 5.0);
    }
    if let Some(speedup) = rep.speedup(
        "service cold (fresh caches per request)",
        "service warm (same batch, different schedule)",
    ) {
        println!("warm schedule-variant speedup: {speedup:.2}×");
        rep.note("service_warm_variant_speedup", speedup);
    }

    section("batch drain (uniap serve)");
    let file: Vec<PlanRequest> = (0..6)
        .map(|i| {
            let mut r = if i % 2 == 0 { req.clone() } else { variant.clone() };
            r.id = format!("batch-{i}");
            r
        })
        .collect();
    rep.bench("serve 6 requests, concurrency 2 (warm service)", 0, s(3), || {
        std::hint::black_box(svc.serve(&file, 2));
    });

    // --- DAG front-end linearization overhead (ISSUE 7) ------------------
    // The front-end's whole cost is one linearize() per cold request
    // (warm requests replay the plan cache and never touch it). Measure
    // it against the cold chain solve it precedes: the fraction is the
    // tax a branching model pays for entering through the DAG IR.
    section("operator-DAG front-end (linearize + plan)");
    let unet = models::dag_by_name("unet").expect("zoo model");
    let (_, unet_report) = linearize(&unet).expect("unet linearizes");
    rep.note("dag_model", "UNet-4-64");
    rep.note("dag_ops", unet_report.num_ops);
    rep.note("dag_virtual_layers", unet_report.virtual_layers.len());
    rep.note("dag_skip_edges", unet_report.skip_edges);
    rep.bench("linearize unet (ops -> virtual layers)", w(10), s(200), || {
        std::hint::black_box(linearize(&unet).expect("unet linearizes"));
    });
    let mut dag_req = PlanRequest::new_dag("dag-cold", unet.clone(), "EnvB", 16);
    dag_req.max_pp = Some(2);
    rep.bench("service cold (unet DAG, fresh caches per request)", w(1), s(3), || {
        let svc = PlannerService::new();
        let resp = svc.plan(&dag_req);
        assert_eq!(resp.status, Status::Ok, "{:?}", resp.error);
        std::hint::black_box(resp);
    });
    if let Some(ratio) = rep.speedup(
        "service cold (fresh caches per request)",
        "linearize unet (ops -> virtual layers)",
    ) {
        let overhead = 1.0 / ratio;
        println!("linearize/cold-solve fraction: {overhead:.5} (gate: <= 0.05)");
        rep.note("dag_linearize_overhead", overhead);
        rep.note("dag_linearize_overhead_target", 0.05);
    }

    // --- socket-served warm requests (ISSUE 4) ---------------------------
    // The long-running `serve --listen` path: the same warm strict-repeat
    // request, now crossing loopback TCP + NDJSON framing. The delta to
    // "service warm (strict repeat)" is the serving overhead per request.
    section("socket serving (serve --listen, loopback)");
    let server = Server::bind("127.0.0.1:0").expect("ephemeral bind");
    let addr = server.local_addr();
    let shutdown = CancelToken::new();
    let server_thread = {
        let svc = svc.clone();
        let shutdown = shutdown.clone();
        std::thread::spawn(move || server.run(&svc, &ServerOptions::default(), &shutdown))
    };
    let stream = TcpStream::connect(addr).expect("connect to own server");
    let read_half = stream.try_clone().expect("clone stream");
    let mut sock_reader = BufReader::new(read_half);
    let mut sock_writer = BufWriter::new(stream);
    let frame = req.to_json().to_string();
    let never = || false;
    let mut socket_round = || -> PlanResponse {
        write_frame(&mut sock_writer, &frame).expect("send");
        let line = read_frame(&mut sock_reader, 1 << 24, &never)
            .expect("read")
            .expect("server alive");
        PlanResponse::parse(&line).expect("typed response")
    };
    let socket_warm = socket_round();
    assert_eq!(socket_warm.status, Status::Ok);
    let identical_socket = plan_to_json(socket_warm.plan.as_ref().unwrap()).to_string()
        == plan_to_json(cold.plan.as_ref().unwrap()).to_string();
    assert!(identical_socket, "socket-served plan differs from the in-process solve");
    rep.note("socket_warm_plan_byte_identical", identical_socket);
    rep.bench("service warm over socket (strict repeat, loopback)", w(2), s(10), || {
        std::hint::black_box(socket_round());
    });
    if let Some(overhead) = rep.speedup(
        "service warm over socket (strict repeat, loopback)",
        "service warm (strict repeat)",
    ) {
        println!("socket overhead on a warm repeat: {overhead:.2}× the in-process time");
        rep.note("socket_warm_overhead_factor", overhead);
    }
    drop(sock_writer);
    drop(sock_reader);
    shutdown.cancel();
    server_thread
        .join()
        .expect("server thread must not panic")
        .expect("server run() must exit cleanly");

    // --- load-shed latency (ISSUE 6) -------------------------------------
    // Admission control's bound: a server with zero in-flight slots must
    // answer `busy` *faster* than a healthy server plans a warm repeat —
    // shedding that costs more than serving would be no protection at
    // all. `shed_latency_vs_warm_socket` records warm-time / shed-time
    // (gate: > 1).
    section("load shedding (admission control, max_inflight 0)");
    let shed_server = Server::bind("127.0.0.1:0").expect("ephemeral bind");
    let shed_addr = shed_server.local_addr();
    let shed_shutdown = CancelToken::new();
    let shed_thread = {
        let svc = svc.clone();
        let shutdown = shed_shutdown.clone();
        let opts = ServerOptions { max_inflight: 0, ..Default::default() };
        std::thread::spawn(move || shed_server.run(&svc, &opts, &shutdown))
    };
    let stream = TcpStream::connect(shed_addr).expect("connect to shed server");
    let read_half = stream.try_clone().expect("clone stream");
    let mut shed_reader = BufReader::new(read_half);
    let mut shed_writer = BufWriter::new(stream);
    let mut shed_round = || -> PlanResponse {
        write_frame(&mut shed_writer, &frame).expect("send");
        let line = read_frame(&mut shed_reader, 1 << 24, &never)
            .expect("read")
            .expect("server alive");
        PlanResponse::parse(&line).expect("typed response")
    };
    let shed = shed_round();
    assert_eq!(shed.status, Status::Busy, "zero slots must shed every request");
    rep.bench("busy shed over socket (max_inflight 0)", w(2), s(10), || {
        std::hint::black_box(shed_round());
    });
    if let Some(ratio) = rep.speedup(
        "service warm over socket (strict repeat, loopback)",
        "busy shed over socket (max_inflight 0)",
    ) {
        println!("shed latency vs warm socket serve: {ratio:.1}× faster to shed");
        rep.note("shed_latency_vs_warm_socket", ratio);
    }
    drop(shed_writer);
    drop(shed_reader);
    shed_shutdown.cancel();
    shed_thread
        .join()
        .expect("shed server thread must not panic")
        .expect("shed server run() must exit cleanly");

    match rep.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench JSON: {e}"),
    }
}
