//! Micro-benchmarks of the planner hot paths (the §Perf iteration log in
//! EXPERIMENTS.md tracks these): interval-DP throughput, full chain solve,
//! MIQP branch-and-bound, cost-matrix construction, simulator iterations,
//! and end-to-end UOP wall time.
//!
//! Every measurement is also written to `BENCH_solver_micro.json`
//! (schema `uniap-bench-v1`) so the sparse-vs-dense speedup is a tracked
//! regression artifact, not a one-off console line. The "before" side is
//! the frozen legacy engine (`planner::chain_dense` + per-candidate cost
//! rebuild, no incumbent sharing); the "after" side is the production
//! sweep. The PR 1 headline rows run single-threaded so the ratio
//! isolates the algorithmic change from thread fan-out; the PR 3 rows do
//! the opposite — they pin the *parallel core* (row-parallel interval DP
//! + frontier memo + candidate fan-out) against the serial baseline,
//! gated at ≥ 2× on a multi-core machine.
//!
//! Run: `cargo bench --bench solver_micro`
//! CI smoke: `UNIAP_BENCH_SMOKE=1` shrinks every row to a single
//! unwarmed sample (and skips the Swin heavyweight) so bench bit-rot is
//! caught without paying full measurement time.

use uniap::cluster::ClusterEnv;
use uniap::cost::{cost_modeling, CostBase, Schedule};
use uniap::graph::models;
use uniap::planner::{chain, chain_dense, uop, PlannerConfig};
use uniap::profiling::Profile;
use uniap::report::bench::{section, BenchReport};
use uniap::sim::{simulate_plan, SimConfig};

/// The pre-refactor UOP: per-candidate cost matrices built from scratch,
/// dense bucket-grid interval DP, no cross-candidate bound sharing, one
/// candidate at a time.
fn uop_dense_reference(
    profile: &Profile,
    graph: &uniap::graph::Graph,
    batch: usize,
    cfg: &PlannerConfig,
) -> Option<f64> {
    let n = profile.env.total_devices();
    let mut cands: Vec<(usize, usize)> = vec![(1, batch)];
    for pp in uniap::util::divisors_except_one(n) {
        if pp > graph.num_layers() {
            continue;
        }
        for c in uniap::util::divisors_except_one(batch) {
            cands.push((pp, c));
        }
    }
    let mut best: Option<f64> = None;
    for (pp, c) in cands {
        let costs = cost_modeling(profile, graph, pp, batch, c);
        if let Some(p) = chain_dense::solve_chain_dense(graph, &costs, cfg) {
            best = Some(best.map_or(p.est_tpi, |b: f64| b.min(p.est_tpi)));
        }
    }
    best
}

fn main() {
    // CI smoke mode: one unwarmed sample per row, heavyweight rows skipped.
    let smoke = std::env::var("UNIAP_BENCH_SMOKE").is_ok();
    let w = |n: usize| if smoke { 0 } else { n };
    let s = |n: usize| if smoke { 1 } else { n };

    let cfg = PlannerConfig::default();
    // PR 1's "before": one sweep worker *and* serial interval rows — the
    // pre-parallel-core planner.
    let serial_core = PlannerConfig { threads: 1, row_helpers: Some(0), ..Default::default() };
    let bert = models::bert_huge();
    let env = ClusterEnv::env_b();
    let profile = Profile::analytic(&env, &bert);
    let mut rep = BenchReport::new("solver_micro");
    rep.note("model", "BERT-Huge");
    rep.note("env", "EnvB");
    rep.note("batch", 16usize);
    if smoke {
        rep.note("smoke", true);
    }

    section("cost model");
    rep.bench("cost_modeling(BERT-Huge, pp=2, c=4)", w(1), s(10), || {
        std::hint::black_box(cost_modeling(&profile, &bert, 2, 16, 4));
    });
    let base2 = CostBase::new(&profile, &bert, 2);
    rep.bench("CostBase::new(BERT-Huge, pp=2)", w(1), s(10), || {
        std::hint::black_box(CostBase::new(&profile, &bert, 2));
    });
    rep.bench("CostBase::materialize(B=16, c=4)", w(1), s(10), || {
        std::hint::black_box(base2.materialize(16, 4, Schedule::GPipe));
    });

    section("chain solver: sparse vs dense grid");
    // Serial rows here: this ratio tracks PR 1's *algorithmic* change
    // (sparse frontiers vs dense grid) across PRs, so the PR 3 row
    // fan-out must stay out of it — the next section measures that.
    let rows0 = PlannerConfig { row_helpers: Some(0), ..Default::default() };
    let costs = cost_modeling(&profile, &bert, 2, 16, 4);
    rep.bench("solve_chain sparse(BERT-Huge, pp=2, c=4)", w(1), s(5), || {
        std::hint::black_box(chain::solve_chain(&bert, &costs, &rows0));
    });
    rep.bench("solve_chain dense (BERT-Huge, pp=2, c=4)", w(1), s(5), || {
        std::hint::black_box(chain_dense::solve_chain_dense(&bert, &costs, &rows0));
    });
    let costs8 = cost_modeling(&profile, &bert, 8, 16, 4);
    rep.bench("solve_chain sparse(BERT-Huge, pp=8, c=4)", w(1), s(5), || {
        std::hint::black_box(chain::solve_chain(&bert, &costs8, &rows0));
    });
    rep.bench("solve_chain dense (BERT-Huge, pp=8, c=4)", w(1), s(5), || {
        std::hint::black_box(chain_dense::solve_chain_dense(&bert, &costs8, &rows0));
    });
    rep.bench("solve_interval(BERT-Huge, 0..33)", w(1), s(10), || {
        std::hint::black_box(chain::solve_interval(&costs, 0, 33));
    });

    section("row-parallel interval DP (ISSUE 3)");
    let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let rows_serial = PlannerConfig { threads: 1, row_helpers: Some(0), ..Default::default() };
    let rows_par =
        PlannerConfig { threads: 1, row_helpers: Some(cores.saturating_sub(1)), ..Default::default() };
    rep.note("row_helpers", cores.saturating_sub(1));
    rep.bench("solve_chain rows SERIAL  (BERT-Huge, pp=2, c=4)", w(1), s(5), || {
        std::hint::black_box(chain::solve_chain(&bert, &costs, &rows_serial));
    });
    rep.bench("solve_chain rows PARALLEL(BERT-Huge, pp=2, c=4)", w(1), s(5), || {
        std::hint::black_box(chain::solve_chain(&bert, &costs, &rows_par));
    });
    if let Some(speedup) = rep.speedup(
        "solve_chain rows SERIAL  (BERT-Huge, pp=2, c=4)",
        "solve_chain rows PARALLEL(BERT-Huge, pp=2, c=4)",
    ) {
        println!("\nrow-parallel interval DP speedup (1 candidate): {speedup:.2}×");
        rep.note("row_parallel_speedup", speedup);
    }

    section("MIQP branch & bound");
    let toy = models::synthetic_chain(8, 5e11, 2e7, 2e6);
    let ptoy = Profile::analytic(&env, &toy);
    let ctoy = cost_modeling(&ptoy, &toy, 4, 8, 4);
    rep.bench("solve_miqp(8 layers, pp=4)", w(1), s(10), || {
        std::hint::black_box(uniap::miqp::solve_miqp(&toy, &ctoy, &cfg));
    });

    section("simulator");
    let plan = chain::solve_chain(&bert, &costs, &cfg).unwrap();
    let sim_cfg = SimConfig::default();
    rep.bench("simulate_plan(BERT-Huge, 5 iters)", w(1), s(20), || {
        std::hint::black_box(simulate_plan(&bert, &profile, &plan, &sim_cfg));
    });

    section("end-to-end UOP: before vs after");
    rep.bench("uop BEFORE dense+rebuild (BERT-Huge, EnvB, B=16, 1 thread)", 0, s(3), || {
        std::hint::black_box(uop_dense_reference(&profile, &bert, 16, &serial_core));
    });
    rep.bench("uop AFTER sparse+reuse (BERT-Huge, EnvB, B=16, serial core)", 0, s(3), || {
        std::hint::black_box(uop(&profile, &bert, 16, &serial_core));
    });
    // PR 3's "after": candidate fan-out + row-parallel interval DP +
    // cross-candidate frontier memo, all budgeted through one pool.
    rep.bench("uop PARALLEL CORE rows+memo (BERT-Huge, EnvB, B=16, threads)", 0, s(3), || {
        std::hint::black_box(uop(&profile, &bert, 16, &cfg));
    });
    if !smoke {
        let swin = models::swin_huge();
        let pswin = Profile::analytic(&ClusterEnv::env_a(), &swin);
        rep.bench("uop(Swin-Huge, EnvA, B=128)", 0, 1, || {
            std::hint::black_box(uop(&pswin, &swin, 128, &cfg));
        });
    }

    if let Some(speedup) = rep.speedup(
        "uop BEFORE dense+rebuild (BERT-Huge, EnvB, B=16, 1 thread)",
        "uop AFTER sparse+reuse (BERT-Huge, EnvB, B=16, serial core)",
    ) {
        println!("\nend-to-end UOP speedup (1 thread, BERT-Huge/EnvB): {speedup:.1}×");
        rep.note("uop_speedup_bert_envb_1thread", speedup);
        rep.note("acceptance_target_speedup", 5.0);
    }
    // PR 3 acceptance gate: the parallel core vs the pre-PR serial
    // planner (PR 1's sparse engine on one thread) must be ≥ 2× on a
    // multi-core machine. Enforced (the bench aborts) on real runs with
    // ≥ 4 cores; recorded but not asserted in smoke mode or on tiny
    // machines where the fan-out has nothing to fan onto.
    if let Some(speedup) = rep.speedup(
        "uop AFTER sparse+reuse (BERT-Huge, EnvB, B=16, serial core)",
        "uop PARALLEL CORE rows+memo (BERT-Huge, EnvB, B=16, threads)",
    ) {
        println!("parallel-core sweep speedup vs serial baseline: {speedup:.2}×");
        rep.note("parallel_core_speedup", speedup);
        rep.note("acceptance_target_parallel_core_speedup", 2.0);
        rep.note("cores", cores);
        if !smoke && cores >= 4 {
            assert!(
                speedup >= 2.0,
                "parallel-core gate failed: {speedup:.2}× < 2× on {cores} cores"
            );
        }
    }
    match rep.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench JSON: {e}"),
    }
}
