//! Micro-benchmarks of the planner hot paths (the §Perf iteration log in
//! EXPERIMENTS.md tracks these): interval-DP throughput, full chain solve,
//! MIQP branch-and-bound, cost-matrix construction, simulator iterations,
//! and end-to-end UOP wall time.
//!
//! Run: `cargo bench --bench solver_micro`

use uniap::cluster::ClusterEnv;
use uniap::cost::cost_modeling;
use uniap::graph::models;
use uniap::planner::{chain, uop, PlannerConfig};
use uniap::profiling::Profile;
use uniap::report::bench::{bench, section};
use uniap::sim::{simulate_plan, SimConfig};

fn main() {
    let cfg = PlannerConfig::default();
    let bert = models::bert_huge();
    let env = ClusterEnv::env_b();
    let profile = Profile::analytic(&env, &bert);

    section("cost model");
    bench("cost_modeling(BERT-Huge, pp=2, c=4)", 1, 10, || {
        std::hint::black_box(cost_modeling(&profile, &bert, 2, 16, 4));
    });

    section("chain solver");
    let costs = cost_modeling(&profile, &bert, 2, 16, 4);
    bench("solve_chain(BERT-Huge, pp=2, c=4)", 1, 5, || {
        std::hint::black_box(chain::solve_chain(&bert, &costs, &cfg));
    });
    let costs8 = cost_modeling(&profile, &bert, 8, 16, 4);
    bench("solve_chain(BERT-Huge, pp=8, c=4)", 1, 5, || {
        std::hint::black_box(chain::solve_chain(&bert, &costs8, &cfg));
    });
    bench("solve_interval(BERT-Huge, 0..33)", 1, 10, || {
        std::hint::black_box(chain::solve_interval(&costs, 0, 33, 128));
    });

    section("MIQP branch & bound");
    let toy = models::synthetic_chain(8, 5e11, 2e7, 2e6);
    let ptoy = Profile::analytic(&env, &toy);
    let ctoy = cost_modeling(&ptoy, &toy, 4, 8, 4);
    bench("solve_miqp(8 layers, pp=4)", 1, 10, || {
        std::hint::black_box(uniap::miqp::solve_miqp(&toy, &ctoy, &cfg));
    });

    section("simulator");
    let plan = chain::solve_chain(&bert, &costs, &cfg).unwrap();
    let sim_cfg = SimConfig::default();
    bench("simulate_plan(BERT-Huge, 5 iters)", 1, 20, || {
        std::hint::black_box(simulate_plan(&bert, &profile, &plan, &sim_cfg));
    });

    section("end-to-end UOP");
    bench("uop(BERT-Huge, EnvB, B=16)", 0, 3, || {
        std::hint::black_box(uop(&profile, &bert, 16, &cfg));
    });
    let swin = models::swin_huge();
    let pswin = Profile::analytic(&ClusterEnv::env_a(), &swin);
    bench("uop(Swin-Huge, EnvA, B=128)", 0, 1, || {
        std::hint::black_box(uop(&pswin, &swin, 128, &cfg));
    });
}
