//! Table 2: ablation on the importance of unifying the strategy space —
//! inter-layer-only and intra-layer-only restrictions vs full UniAP on
//! EnvB (B = 16 / 12 / 64 / 32).
//!
//! Run: `cargo bench --bench table2_ablation`

use uniap::baselines::{Baseline, BaselineKind};
use uniap::cluster::ClusterEnv;
use uniap::graph::models;
use uniap::planner::PlannerConfig;
use uniap::profiling::Profile;
use uniap::report::Table;
use uniap::sim::{simulate_plan, SimConfig};

fn main() {
    let cfg = PlannerConfig::default();
    let env = ClusterEnv::env_b();
    let workloads: Vec<(&str, usize)> =
        vec![("bert", 16), ("t5-16", 12), ("vit", 64), ("swin", 32)];
    println!("# Table 2 — ablation on strategy-space unification (EnvB)\n");
    let mut table = Table::new(&["model", "Inter-only", "Intra-only", "UniAP"]);
    for (name, batch) in workloads {
        let graph = models::by_name(name).unwrap();
        let profile = Profile::analytic(&env, &graph);
        let mut cells = Vec::new();
        for kind in [BaselineKind::InterOnly, BaselineKind::IntraOnly, BaselineKind::UniAP] {
            let r = Baseline::run(kind, &profile, &graph, batch, &cfg);
            let cell = match r.plan {
                None => "SOL×".to_string(),
                Some(plan) => {
                    let sim = simulate_plan(&graph, &profile, &plan, &SimConfig::default());
                    if sim.oom {
                        "CUDA×".to_string()
                    } else {
                        uniap::metrics::pm(sim.throughput, sim.throughput_std, 2)
                    }
                }
            };
            cells.push(cell);
        }
        table.row(vec![graph.name.clone(), cells[0].clone(), cells[1].clone(), cells[2].clone()]);
    }
    print!("{}", table.to_markdown());
    println!("\npaper shape: restrictions lose throughput or fail outright; UniAP never loses.");
}
