//! Figure 4: scalability on EnvD — (a) training throughput of the optimal
//! strategy and (b) strategy optimization time, as nodes grow 1 → 4 with
//! proportionally growing mini-batches (8/4/32/16 × #nodes).
//!
//! Per-model strategy-optimization wall times are also written to
//! `BENCH_fig4_scalability.json` so the Figure 4b trend is tracked across
//! PRs (EXPERIMENTS.md §Perf).
//!
//! Run: `cargo bench --bench fig4_scalability`

use uniap::cluster::ClusterEnv;
use uniap::graph::models;
use uniap::planner::{uop, PlannerConfig};
use uniap::profiling::Profile;
use uniap::report::bench::BenchReport;
use uniap::report::Table;
use uniap::sim::{simulate_plan, SimConfig};

fn main() {
    let cfg = PlannerConfig::default();
    let mut rep = BenchReport::new("fig4_scalability");
    rep.note("env", "EnvD");
    let specs: Vec<(&str, usize)> = vec![("bert", 8), ("t5-16", 4), ("vit", 32), ("swin", 16)];
    println!("# Figure 4a — throughput (samples/s) vs #nodes (EnvD)\n");
    let mut thr = Table::new(&["model", "1 node", "2 nodes", "4 nodes", "4n/1n ratio"]);
    let mut opt = Table::new(&["model", "1 node", "2 nodes", "4 nodes"]);
    for (name, b_per_node) in specs {
        let graph = models::by_name(name).unwrap();
        let mut thr_cells = Vec::new();
        let mut opt_cells = Vec::new();
        let mut first = 0.0;
        let mut last = 0.0;
        for nodes in [1usize, 2, 4] {
            let env = ClusterEnv::env_d_nodes(nodes);
            let profile = Profile::analytic(&env, &graph);
            let res = uop(&profile, &graph, b_per_node * nodes, &cfg);
            opt_cells.push(uniap::util::fmt_secs(res.wall_secs));
            rep.note(&format!("opt_secs/{name}/{nodes}n"), res.wall_secs);
            match res.best {
                Some(plan) => {
                    let sim = simulate_plan(&graph, &profile, &plan, &SimConfig::default());
                    if nodes == 1 {
                        first = sim.throughput;
                    }
                    last = sim.throughput;
                    thr_cells.push(format!("{:.2}", sim.throughput));
                }
                None => thr_cells.push("SOL×".to_string()),
            }
        }
        thr.row(vec![
            graph.name.clone(),
            thr_cells[0].clone(),
            thr_cells[1].clone(),
            thr_cells[2].clone(),
            format!("{:.2}", last / first.max(1e-9)),
        ]);
        opt.row(vec![
            graph.name.clone(),
            opt_cells[0].clone(),
            opt_cells[1].clone(),
            opt_cells[2].clone(),
        ]);
    }
    print!("{}", thr.to_markdown());
    println!("\n# Figure 4b — strategy optimization time vs #nodes\n");
    print!("{}", opt.to_markdown());
    println!("\npaper shape: near-linear throughput scaling; optimization time grows");
    println!("with the candidate count O(√(B·d)) per the §3.5 complexity analysis.");
    match rep.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench JSON: {e}"),
    }
}
