"""Layer-1: fused causal flash-attention as a Pallas kernel (TPU-style).

Hardware adaptation of the paper's CUDA substrate (DESIGN.md
§Hardware-Adaptation): instead of a threadblock decomposition, the
HBM↔VMEM schedule is expressed with a Pallas grid over (batch·heads,
query blocks) and `BlockSpec`s sized for VMEM residency; the contraction
shapes are MXU-friendly (the query block × head-dim tiles), and the softmax
is computed online (block-wise running max/sum rescaling) so no s×s score
matrix ever materialises.

Lowered with `interpret=True`: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret mode lowers the kernel to plain HLO that any
backend runs (see /opt/xla-example/README.md). Real-TPU VMEM/MXU estimates
are recorded in DESIGN.md §Perf.

The backward pass is the exact VJP of the pure-jnp oracle (`ref.attention`)
via `jax.custom_vjp` — AD never differentiates through the Pallas call.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

_NEG = -1e30  # finite "-inf" so fully-masked blocks stay NaN-free


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, block_q, block_k, seq):
    """One (batch·head, q-block) grid cell: online-softmax attention."""
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale  # [bq, dh]
    dh = q.shape[-1]
    rows = qi * block_q + jax.lax.iota(jnp.int32, block_q)  # global q index

    num_k = seq // block_k

    def body(kb, carry):
        m, l, acc = carry
        k_blk = k_ref[pl.dslice(kb * block_k, block_k), :]
        v_blk = v_ref[pl.dslice(kb * block_k, block_k), :]
        k_blk = k_blk.astype(jnp.float32)
        v_blk = v_blk.astype(jnp.float32)
        cols = kb * block_k + jax.lax.iota(jnp.int32, block_k)
        logits = q @ k_blk.T  # [bq, bk] — MXU contraction
        logits = jnp.where(cols[None, :] <= rows[:, None], logits, _NEG)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[:, None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + p @ v_blk
        return m_new, l_new, acc_new

    m0 = jnp.full((block_q,), _NEG, dtype=jnp.float32)
    l0 = jnp.zeros((block_q,), dtype=jnp.float32)
    acc0 = jnp.zeros((block_q, dh), dtype=jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, num_k, body, (m0, l0, acc0))
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


def _pick_block(s, want):
    """Largest divisor of `s` that is ≤ `want` (block shapes must tile s)."""
    b = min(want, s)
    while s % b != 0:
        b -= 1
    return b


def flash_attention(q, k, v, *, block_q=64, block_k=64):
    """Causal flash attention over [b, h, s, dh]; Pallas, interpret mode."""
    b, h, s, dh = q.shape
    scale = 1.0 / float(dh) ** 0.5
    bq = _pick_block(s, block_q)
    bk = _pick_block(s, block_k)
    q2 = q.reshape(b * h, s, dh)
    k2 = k.reshape(b * h, s, dh)
    v2 = v.reshape(b * h, s, dh)
    kernel = functools.partial(_flash_kernel, scale=scale, block_q=bq, block_k=bk, seq=s)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, s // bq),
        in_specs=[
            pl.BlockSpec((None, bq, dh), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, s, dh), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((None, s, dh), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, dh), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, dh), q.dtype),
        interpret=True,
    )(q2, k2, v2)
    return out.reshape(b, h, s, dh)


@jax.custom_vjp
def attention(q, k, v):
    """Causal attention: Pallas forward, oracle-exact backward."""
    return flash_attention(q, k, v)


def _attn_fwd(q, k, v):
    return flash_attention(q, k, v), (q, k, v)


def _attn_bwd(res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q, k, v: _ref_causal(q, k, v), q, k, v)
    return vjp(g)


def _ref_causal(q, k, v):
    return ref.attention(q, k, v)


attention.defvjp(_attn_fwd, _attn_bwd)


def vmem_estimate_bytes(s, dh, block_q=64, block_k=64, dtype_bytes=4):
    """Per-grid-cell VMEM footprint estimate for DESIGN.md §Perf: the q
    tile, one k/v block pair, the logits tile and the accumulator."""
    bq = _pick_block(s, block_q)
    bk = _pick_block(s, block_k)
    tiles = bq * dh + 2 * bk * dh + bq * bk + bq * dh + 2 * bq
    return tiles * dtype_bytes
