"""Pure-jnp reference oracles for the Pallas kernels (Layer-1 correctness).

Every Pallas kernel in this package has an exact mathematical counterpart
here; pytest + hypothesis assert allclose between the two across shapes and
dtypes. These references are also the custom-VJP backward implementations,
so gradients flowing through the Pallas forward are exactly the gradients
of this math.
"""

import jax.numpy as jnp


def attention(q, k, v, scale=None):
    """Causal scaled dot-product attention over [b, h, s, dh] tensors."""
    _, _, s, dh = q.shape
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(dh, dtype=q.dtype))
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    logits = jnp.where(mask[None, None, :, :], logits, jnp.finfo(logits.dtype).min)
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def layernorm(x, gamma, beta, eps=1e-5):
    """LayerNorm over the last axis."""
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta


def ffn_gelu(x, w1, b1, w2, b2):
    """Position-wise feed-forward with tanh-GELU."""
    h = x @ w1 + b1
    h = 0.5 * h * (1.0 + jnp.tanh(0.7978845608028654 * (h + 0.044715 * h**3)))
    return h @ w2 + b2
