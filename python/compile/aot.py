"""AOT export: lower the Layer-2 stage programs to HLO text artifacts.

HLO *text* is the interchange format — NOT `lowered.compile().serialize()`
and NOT the serialized HloModuleProto: jax ≥ 0.5 emits protos with 64-bit
instruction ids which the Rust side's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Also writes:
  meta.txt          — key=value export configuration (rust parses this)
  init_stage<i>.bin — initial flat parameters, f32 little-endian

Usage:  python -m compile.aot --out ../artifacts [--preset small|e2e]
        [--stages N] [--micro-batch B] [--seq S] [--d D] [--layers L]
"""

import argparse
import os

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from .model import GPTConfig, init_stage, make_entry_points, spec_size, stage_roles, stage_spec

PRESETS = {
    # fast export + fast tests
    "small": dict(vocab=512, d=128, layers=4, heads=4, seq=64, micro_batch=4, stages=2),
    # the end-to-end example: ~26M parameters, 4 pipeline stages
    "e2e": dict(vocab=4096, d=384, layers=12, heads=6, seq=64, micro_batch=4, stages=4),
}


def to_hlo_text(fn, example_args) -> str:
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export(cfg: GPTConfig, out_dir: str, seed: int = 0, verbose: bool = True) -> None:
    os.makedirs(out_dir, exist_ok=True)
    entries = make_entry_points(cfg)
    for name, (fn, args) in entries.items():
        text = to_hlo_text(fn, args)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        if verbose:
            print(f"wrote {path} ({len(text)} chars)")

    roles = stage_roles(cfg.stages)
    key = jax.random.PRNGKey(seed)
    sizes = []
    for i, role in enumerate(roles):
        key, sub = jax.random.split(key)
        flat = np.asarray(init_stage(cfg, role, sub), dtype=np.float32)
        sizes.append(flat.size)
        flat.tofile(os.path.join(out_dir, f"init_stage{i}.bin"))
        if verbose:
            print(f"wrote init_stage{i}.bin ({flat.size} params, role={role})")

    meta = [
        f"vocab={cfg.vocab}",
        f"d={cfg.d}",
        f"layers={cfg.layers}",
        f"heads={cfg.heads}",
        f"seq={cfg.seq}",
        f"micro_batch={cfg.micro_batch}",
        f"stages={cfg.stages}",
    ]
    meta += [f"params_stage{i}={n}" for i, n in enumerate(sizes)]
    with open(os.path.join(out_dir, "meta.txt"), "w") as f:
        f.write("\n".join(meta) + "\n")
    if verbose:
        total = sum(sizes)
        print(f"wrote meta.txt — {total/1e6:.2f}M params over {cfg.stages} stages")
        for r in ("first", "mid", "last"):
            print(f"  role {r}: {spec_size(stage_spec(cfg, r))} params")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--preset", default="small", choices=sorted(PRESETS))
    ap.add_argument("--stages", type=int)
    ap.add_argument("--micro-batch", type=int)
    ap.add_argument("--seq", type=int)
    ap.add_argument("--d", type=int)
    ap.add_argument("--layers", type=int)
    ap.add_argument("--vocab", type=int)
    ap.add_argument("--heads", type=int)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    kw = dict(PRESETS[args.preset])
    for field in ("stages", "micro_batch", "seq", "d", "layers", "vocab", "heads"):
        v = getattr(args, field)
        if v is not None:
            kw[field] = v
    cfg = GPTConfig(**kw)
    export(cfg, args.out, seed=args.seed)


if __name__ == "__main__":
    main()
