"""Layer-2: the JAX transformer LM, staged for pipeline execution.

Build-time only — `aot.py` lowers these functions once to HLO text; the
Rust coordinator executes the artifacts through PJRT and Python never runs
again.

Parameters of a pipeline stage live in ONE flat f32 vector. The layout is
spec-driven (`stage_spec`) so packing (init) and unpacking (forward) share
a single source of truth, and the Rust side only ever sees opaque flat
buffers plus their total length (`meta.txt`).

Stage roles (see rust/src/exec/pipeline.rs for the artifact contract):
  first : embedding + first `layers/stages` blocks
  mid   : blocks only
  last  : blocks + final layernorm + LM head + mean-token cross-entropy

Backward stage programs recompute their forward internally
(rematerialisation), so pipeline traffic is exactly activations forward /
activation-gradients backward. Attention is the Layer-1 Pallas kernel
(`kernels.attention`), wrapped in a custom VJP.
"""

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.attention import attention


@dataclass(frozen=True)
class GPTConfig:
    vocab: int = 512
    d: int = 128
    layers: int = 4
    heads: int = 4
    seq: int = 64
    micro_batch: int = 4
    stages: int = 2

    @property
    def ff(self):
        return 4 * self.d

    @property
    def layers_per_stage(self):
        assert self.layers % self.stages == 0, "stages must divide layers"
        return self.layers // self.stages


# ---------------------------------------------------------------------------
# spec-driven flat parameter layout
# ---------------------------------------------------------------------------

def block_spec(cfg, prefix):
    d, ff = cfg.d, cfg.ff
    return [
        (f"{prefix}.ln1_g", (d,), "one"),
        (f"{prefix}.ln1_b", (d,), "zero"),
        (f"{prefix}.wqkv", (d, 3 * d), "w"),
        (f"{prefix}.bqkv", (3 * d,), "zero"),
        (f"{prefix}.wo", (d, d), "w"),
        (f"{prefix}.bo", (d,), "zero"),
        (f"{prefix}.ln2_g", (d,), "one"),
        (f"{prefix}.ln2_b", (d,), "zero"),
        (f"{prefix}.w1", (d, ff), "w"),
        (f"{prefix}.b1", (ff,), "zero"),
        (f"{prefix}.w2", (ff, d), "w"),
        (f"{prefix}.b2", (d,), "zero"),
    ]


def stage_spec(cfg, role):
    """Tensor spec [(name, shape, init)] for one stage's flat buffer."""
    assert role in ("first", "mid", "last")
    spec = []
    if role == "first":
        spec.append(("embed", (cfg.vocab, cfg.d), "w"))
        spec.append(("pos", (cfg.seq, cfg.d), "w"))
    for i in range(cfg.layers_per_stage):
        spec.extend(block_spec(cfg, f"blk{i}"))
    if role == "last":
        spec.append(("lnf_g", (cfg.d,), "one"))
        spec.append(("lnf_b", (cfg.d,), "zero"))
        spec.append(("whead", (cfg.d, cfg.vocab), "w"))
    return spec


def spec_size(spec):
    size = 0
    for _, shape, _ in spec:
        n = 1
        for s in shape:
            n *= s
        size += n
    return size


def unpack(flat, spec):
    """Slice a flat vector into named tensors (static shapes → static HLO)."""
    out, at = {}, 0
    for name, shape, _ in spec:
        n = 1
        for s in shape:
            n *= s
        out[name] = flat[at : at + n].reshape(shape)
        at += n
    return out


def init_stage(cfg, role, key):
    """Initial flat parameter vector for one stage."""
    spec = stage_spec(cfg, role)
    chunks = []
    for name, shape, kind in spec:
        n = 1
        for s in shape:
            n *= s
        if kind == "w":
            key, sub = jax.random.split(key)
            chunks.append(0.02 * jax.random.normal(sub, (n,), jnp.float32))
        elif kind == "one":
            chunks.append(jnp.ones((n,), jnp.float32))
        else:
            chunks.append(jnp.zeros((n,), jnp.float32))
    return jnp.concatenate(chunks)


# ---------------------------------------------------------------------------
# forward math
# ---------------------------------------------------------------------------

def _block(x, p, prefix, cfg):
    b, s, d = x.shape
    h = ref.layernorm(x, p[f"{prefix}.ln1_g"], p[f"{prefix}.ln1_b"])
    qkv = h @ p[f"{prefix}.wqkv"] + p[f"{prefix}.bqkv"]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    dh = d // cfg.heads
    to_heads = lambda t: t.reshape(b, s, cfg.heads, dh).transpose(0, 2, 1, 3)
    a = attention(to_heads(q), to_heads(k), to_heads(v))
    a = a.transpose(0, 2, 1, 3).reshape(b, s, d)
    x = x + a @ p[f"{prefix}.wo"] + p[f"{prefix}.bo"]
    h = ref.layernorm(x, p[f"{prefix}.ln2_g"], p[f"{prefix}.ln2_b"])
    x = x + ref.ffn_gelu(h, p[f"{prefix}.w1"], p[f"{prefix}.b1"], p[f"{prefix}.w2"], p[f"{prefix}.b2"])
    return x


def _run_blocks(x, p, cfg):
    for i in range(cfg.layers_per_stage):
        x = _block(x, p, f"blk{i}", cfg)
    return x


def first_fwd(cfg, params, tokens):
    """first stage: (flat params, tokens[b,s] i32) → h[b,s,d]."""
    p = unpack(params, stage_spec(cfg, "first"))
    x = p["embed"][tokens] + p["pos"][None, :, :]
    return _run_blocks(x, p, cfg)


def mid_fwd(cfg, params, h):
    """mid stage: (flat params, h_in) → h_out."""
    p = unpack(params, stage_spec(cfg, "mid"))
    return _run_blocks(h, p, cfg)


def last_loss(cfg, params, h, targets):
    """last stage: (flat params, h_in, targets[b,s] i32) → mean CE loss."""
    p = unpack(params, stage_spec(cfg, "last"))
    h = _run_blocks(h, p, cfg)
    h = ref.layernorm(h, p["lnf_g"], p["lnf_b"])
    logits = h @ p["whead"]
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - picked)


# ---------------------------------------------------------------------------
# exported entry points (what aot.py lowers) — all tuple-returning
# ---------------------------------------------------------------------------

def make_entry_points(cfg):
    """Return {artifact name: (fn, example_args)} for AOT lowering."""
    b, s, d = cfg.micro_batch, cfg.seq, cfg.d
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    act = jax.ShapeDtypeStruct((b, s, d), jnp.float32)
    roles = stage_roles(cfg.stages)
    sizes = {r: spec_size(stage_spec(cfg, r)) for r in ("first", "mid", "last")}
    pf = jax.ShapeDtypeStruct((sizes["first"],), jnp.float32)
    pm = jax.ShapeDtypeStruct((sizes["mid"],), jnp.float32)
    pl_ = jax.ShapeDtypeStruct((sizes["last"],), jnp.float32)

    def first_fwd_e(params, tokens):
        return (first_fwd(cfg, params, tokens),)

    def first_bwd_e(params, tokens, g_h):
        g = jax.vjp(lambda p: first_fwd(cfg, p, tokens), params)[1](g_h)[0]
        return (g,)

    def mid_fwd_e(params, h):
        return (mid_fwd(cfg, params, h),)

    def mid_bwd_e(params, h, g_out):
        _, vjp = jax.vjp(lambda p, x: mid_fwd(cfg, p, x), params, h)
        gp, gh = vjp(g_out)
        return (gp, gh)

    def last_bwd_e(params, h, targets):
        loss, vjp = jax.value_and_grad(
            lambda p, x: last_loss(cfg, p, x, targets), argnums=(0, 1)
        )(params, h)
        gp, gh = vjp
        return (loss, gp, gh)

    def full_step_e(*args):
        stage_params = args[: cfg.stages]
        tokens, targets = args[cfg.stages], args[cfg.stages + 1]

        def loss_fn(ps):
            h = first_fwd(cfg, ps[0], tokens)
            for si in range(1, cfg.stages - 1):
                h = mid_fwd(cfg, ps[si], h)
            return last_loss(cfg, ps[-1], h, targets)

        loss, grads = jax.value_and_grad(loss_fn)(list(stage_params))
        return (loss, *grads)

    entries = {
        "stage_first_fwd": (first_fwd_e, (pf, tok)),
        "stage_first_bwd": (first_bwd_e, (pf, tok, act)),
        "stage_last_bwd": (last_bwd_e, (pl_, act, tok)),
    }
    if cfg.stages > 2:
        entries["stage_mid_fwd"] = (mid_fwd_e, (pm, act))
        entries["stage_mid_bwd"] = (mid_bwd_e, (pm, act, act))
    full_args = tuple(
        {"first": pf, "mid": pm, "last": pl_}[r] for r in roles
    ) + (tok, tok)
    entries["full_step"] = (full_step_e, full_args)
    return entries


def stage_roles(stages):
    """Role of each pipeline stage index."""
    assert stages >= 2, "pipeline needs ≥ 2 stages"
    return ["first"] + ["mid"] * (stages - 2) + ["last"]


# convenience for tests
def reference_loss(cfg, stage_params, tokens, targets):
    """Compose stages in pure JAX (no pipeline) — test oracle."""
    h = first_fwd(cfg, stage_params[0], tokens)
    for si in range(1, cfg.stages - 1):
        h = mid_fwd(cfg, stage_params[si], h)
    return last_loss(cfg, stage_params[-1], h, targets)


partial  # re-exported convenience (silences linters about unused import)
