"""AOT export round-trip: artifacts are valid HLO text with the contract's
shapes, and meta/init files are mutually consistent."""

import os
import re
import tempfile

import numpy as np
import pytest

from compile.aot import PRESETS, export, to_hlo_text
from compile.model import GPTConfig, make_entry_points

CFG = GPTConfig(vocab=64, d=16, layers=2, heads=2, seq=8, micro_batch=2, stages=2)


@pytest.fixture(scope="module")
def out_dir():
    with tempfile.TemporaryDirectory() as d:
        export(CFG, d, verbose=False)
        yield d


def test_all_artifacts_written(out_dir):
    names = {
        "stage_first_fwd.hlo.txt",
        "stage_first_bwd.hlo.txt",
        "stage_last_bwd.hlo.txt",
        "full_step.hlo.txt",
        "meta.txt",
        "init_stage0.bin",
        "init_stage1.bin",
    }
    assert names <= set(os.listdir(out_dir))


def test_hlo_text_is_tuple_rooted_and_parses(out_dir):
    text = open(os.path.join(out_dir, "stage_first_fwd.hlo.txt")).read()
    assert text.startswith("HloModule")
    # entry layout: (params, s32 tokens) -> (activation,)
    m = re.search(r"entry_computation_layout=\{\((.*?)\)->\((.*?)\)\}", text)
    assert m, "no entry layout"
    assert "s32[2,8]" in m.group(1)
    assert f"f32[2,8,{CFG.d}]" in m.group(2)


def test_meta_matches_init_sizes(out_dir):
    meta = dict(
        line.split("=") for line in open(os.path.join(out_dir, "meta.txt")) if "=" in line
    )
    assert int(meta["vocab"]) == CFG.vocab
    assert int(meta["stages"]) == CFG.stages
    for i in range(CFG.stages):
        blob = np.fromfile(os.path.join(out_dir, f"init_stage{i}.bin"), dtype=np.float32)
        assert blob.size == int(meta[f"params_stage{i}"])
        assert np.isfinite(blob).all()


def test_init_is_deterministic_per_seed():
    with tempfile.TemporaryDirectory() as a, tempfile.TemporaryDirectory() as b:
        export(CFG, a, seed=1, verbose=False)
        export(CFG, b, seed=1, verbose=False)
        x = np.fromfile(os.path.join(a, "init_stage0.bin"), dtype=np.float32)
        y = np.fromfile(os.path.join(b, "init_stage0.bin"), dtype=np.float32)
        np.testing.assert_array_equal(x, y)


def test_lowering_contains_no_python_callbacks(out_dir):
    """The artifact must be self-contained HLO (no host callbacks): the
    Pallas kernel lowered via interpret mode to plain ops."""
    for name in ("stage_first_fwd", "full_step"):
        text = open(os.path.join(out_dir, f"{name}.hlo.txt")).read()
        assert "custom-call" not in text or "Sharding" in text, name


def test_presets_are_exportable_shapes():
    for name, kw in PRESETS.items():
        cfg = GPTConfig(**kw)
        assert cfg.layers % cfg.stages == 0, name
        assert cfg.d % cfg.heads == 0, name


def test_to_hlo_text_small_function():
    import jax.numpy as jnp

    def f(x):
        return (x * 2.0,)

    import jax

    text = to_hlo_text(f, (jax.ShapeDtypeStruct((4,), jnp.float32),))
    assert text.startswith("HloModule")
    assert "ROOT" in text
