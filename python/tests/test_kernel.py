"""L1 correctness: Pallas flash-attention vs the pure-jnp oracle.

The hypothesis sweep covers shapes (batch, heads, seq, head-dim), block
sizes (including non-dividing requests that trigger the divisor fallback)
and dtypes; equality is asserted against `ref.attention` — the CORE
correctness signal for the kernel layer.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.kernels.attention import attention, flash_attention, vmem_estimate_bytes

hypothesis.settings.register_profile(
    "kernel", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("kernel")


def rand_qkv(key, b, h, s, dh, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return [jax.random.normal(k, (b, h, s, dh), dtype) for k in ks]


@hypothesis.given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    s=st.sampled_from([8, 24, 64, 96]),
    dh=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**30),
)
def test_flash_matches_reference(b, h, s, dh, seed):
    q, k, v = rand_qkv(jax.random.PRNGKey(seed), b, h, s, dh)
    got = flash_attention(q, k, v)
    want = ref.attention(q, k, v)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


@hypothesis.given(
    bq=st.sampled_from([8, 16, 48, 64, 100]),
    bk=st.sampled_from([8, 16, 48, 64, 100]),
)
def test_block_sizes_do_not_change_results(bq, bk):
    q, k, v = rand_qkv(jax.random.PRNGKey(7), 2, 2, 48, 16)
    got = flash_attention(q, k, v, block_q=bq, block_k=bk)
    want = ref.attention(q, k, v)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_bfloat16_inputs_supported():
    q, k, v = rand_qkv(jax.random.PRNGKey(3), 1, 2, 32, 16, jnp.bfloat16)
    got = flash_attention(q, k, v).astype(jnp.float32)
    want = ref.attention(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32)
    )
    np.testing.assert_allclose(got, want, rtol=3e-2, atol=3e-2)


def test_causality():
    """Future keys must not influence earlier queries."""
    q, k, v = rand_qkv(jax.random.PRNGKey(5), 1, 1, 32, 8)
    out1 = flash_attention(q, k, v)
    k2 = k.at[:, :, 20:, :].set(99.0)
    v2 = v.at[:, :, 20:, :].set(-99.0)
    out2 = flash_attention(q, k2, v2)
    np.testing.assert_allclose(out1[:, :, :20], out2[:, :, :20], rtol=1e-6, atol=1e-6)
    assert not np.allclose(out1[:, :, 20:], out2[:, :, 20:])


def test_rows_attend_to_self_first_row_is_v0():
    """Causal row 0 can only attend to key 0 → output is exactly v[0]."""
    q, k, v = rand_qkv(jax.random.PRNGKey(11), 1, 1, 16, 8)
    out = flash_attention(q, k, v)
    np.testing.assert_allclose(out[0, 0, 0], v[0, 0, 0], rtol=1e-6, atol=1e-6)


def test_custom_vjp_grads_match_reference_grads():
    q, k, v = rand_qkv(jax.random.PRNGKey(9), 2, 2, 32, 16)
    f_pallas = lambda q, k, v: (attention(q, k, v) ** 2).sum()
    f_ref = lambda q, k, v: (ref.attention(q, k, v) ** 2).sum()
    gp = jax.grad(f_pallas, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gp, gr):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


def test_jit_and_grad_compose():
    q, k, v = rand_qkv(jax.random.PRNGKey(13), 1, 2, 24, 8)
    loss = jax.jit(lambda q, k, v: attention(q, k, v).sum())
    g = jax.jit(jax.grad(lambda q, k, v: attention(q, k, v).sum()))
    assert np.isfinite(float(loss(q, k, v)))
    assert np.isfinite(np.asarray(g(q, k, v)).sum())


@pytest.mark.parametrize("s,dh", [(64, 32), (128, 64), (2048, 128)])
def test_vmem_estimate_within_budget(s, dh):
    """BlockSpec tiles must fit a 16 MiB VMEM budget (DESIGN.md §Perf)."""
    assert vmem_estimate_bytes(s, dh) < 16 * 2**20


def test_layernorm_reference_properties():
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32))
    y = ref.layernorm(x, jnp.ones(32), jnp.zeros(32))
    np.testing.assert_allclose(np.asarray(y.mean(-1)), 0.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y.std(-1)), 1.0, atol=1e-2)
