"""L2 correctness: staged transformer vs single-program composition."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    GPTConfig,
    first_fwd,
    init_stage,
    last_loss,
    make_entry_points,
    mid_fwd,
    reference_loss,
    spec_size,
    stage_roles,
    stage_spec,
    unpack,
)

CFG = GPTConfig(vocab=128, d=32, layers=4, heads=2, seq=16, micro_batch=2, stages=2)
CFG4 = GPTConfig(vocab=128, d=32, layers=4, heads=2, seq=16, micro_batch=2, stages=4)


def stage_params(cfg, seed=0):
    key = jax.random.PRNGKey(seed)
    out = []
    for role in stage_roles(cfg.stages):
        key, sub = jax.random.split(key)
        out.append(init_stage(cfg, role, sub))
    return out


def batch(cfg, seed=1):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    toks = jax.random.randint(k1, (cfg.micro_batch, cfg.seq), 0, cfg.vocab)
    tgts = jax.random.randint(k2, (cfg.micro_batch, cfg.seq), 0, cfg.vocab)
    return toks, tgts


def test_spec_sizes_match_init():
    for role in ("first", "mid", "last"):
        flat = init_stage(CFG, role, jax.random.PRNGKey(0))
        assert flat.shape == (spec_size(stage_spec(CFG, role)),)


def test_unpack_layout_roundtrip():
    spec = stage_spec(CFG, "last")
    flat = init_stage(CFG, "last", jax.random.PRNGKey(2))
    p = unpack(flat, spec)
    assert p["whead"].shape == (CFG.d, CFG.vocab)
    assert p["lnf_g"].shape == (CFG.d,)
    # layernorm gains initialise to 1, biases to 0
    np.testing.assert_allclose(p["lnf_g"], 1.0)
    np.testing.assert_allclose(p["lnf_b"], 0.0)
    # re-concatenation reproduces the flat buffer
    rebuilt = jnp.concatenate([p[n].reshape(-1) for n, _, _ in spec])
    np.testing.assert_array_equal(rebuilt, flat)


def test_forward_shapes():
    ps = stage_params(CFG)
    toks, tgts = batch(CFG)
    h = first_fwd(CFG, ps[0], toks)
    assert h.shape == (CFG.micro_batch, CFG.seq, CFG.d)
    loss = last_loss(CFG, ps[1], h, tgts)
    assert loss.shape == ()
    assert float(loss) == pytest.approx(np.log(CFG.vocab), rel=0.1)


def test_mid_stage_composes():
    ps = stage_params(CFG4)
    toks, tgts = batch(CFG4)
    h = first_fwd(CFG4, ps[0], toks)
    h = mid_fwd(CFG4, ps[1], h)
    h = mid_fwd(CFG4, ps[2], h)
    loss = last_loss(CFG4, ps[3], h, tgts)
    assert np.isfinite(float(loss))
    assert float(loss) == pytest.approx(float(reference_loss(CFG4, ps, toks, tgts)), abs=1e-6)


@pytest.mark.parametrize("cfg", [CFG, CFG4], ids=["2stage", "4stage"])
def test_staged_grads_equal_full_grads(cfg):
    """The decisive L2 invariant: composing per-stage VJPs (what the Rust
    executor does) reproduces jax.grad of the whole model."""
    ps = stage_params(cfg, seed=3)
    toks, tgts = batch(cfg, seed=4)
    entries = make_entry_points(cfg)

    # full_step reference
    full = entries["full_step"][0]
    full_out = full(*ps, toks, tgts)
    loss_full, grads_full = full_out[0], full_out[1:]

    # manual stage composition, like the executor
    roles = stage_roles(cfg.stages)
    h = first_fwd(cfg, ps[0], toks)
    acts = {0: None}
    hs = [None, h]
    for si in range(1, cfg.stages - 1):
        h = mid_fwd(cfg, ps[si], h)
        hs.append(h)
    loss, (gp_last, gh) = jax.value_and_grad(
        lambda p, x: last_loss(cfg, p, x, tgts), argnums=(0, 1)
    )(ps[-1], hs[-1])
    grads = {cfg.stages - 1: gp_last}
    for si in range(cfg.stages - 2, 0, -1):
        _, vjp = jax.vjp(lambda p, x: mid_fwd(cfg, p, x), ps[si], hs[si])
        gp, gh = vjp(gh)
        grads[si] = gp
    gp0 = jax.vjp(lambda p: first_fwd(cfg, p, toks), ps[0])[1](gh)[0]
    grads[0] = gp0

    assert float(loss) == pytest.approx(float(loss_full), abs=1e-6)
    for si in range(cfg.stages):
        np.testing.assert_allclose(
            grads[si], grads_full[si], rtol=1e-4, atol=1e-5,
            err_msg=f"stage {si} ({roles[si]})",
        )
    del acts


def test_entry_points_cover_contract():
    e2 = make_entry_points(CFG)
    assert set(e2) == {"stage_first_fwd", "stage_first_bwd", "stage_last_bwd", "full_step"}
    e4 = make_entry_points(CFG4)
    assert {"stage_mid_fwd", "stage_mid_bwd"} <= set(e4)


def test_loss_decreases_under_sgd():
    """Sanity: a few full-batch steps reduce the loss on fixed data."""
    ps = stage_params(CFG, seed=5)
    toks, tgts = batch(CFG, seed=6)
    loss_fn = jax.jit(lambda ps: reference_loss(CFG, ps, toks, tgts))
    grad_fn = jax.jit(jax.grad(lambda ps: reference_loss(CFG, ps, toks, tgts)))
    l0 = float(loss_fn(ps))
    for _ in range(10):
        g = grad_fn(ps)
        ps = [p - 0.5 * gi for p, gi in zip(ps, g)]
    l1 = float(loss_fn(ps))
    assert l1 < l0 - 0.05, f"{l0} → {l1}"
