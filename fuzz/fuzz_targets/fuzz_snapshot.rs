//! Fuzz snapshot loading: `Snapshot::parse` over arbitrary text must
//! never panic (damaged state files degrade to cold starts, they do not
//! kill serving), and any snapshot that does validate round-trips and is
//! idempotent under self-merge on the emitted bytes — the property the
//! state_merge battery asserts for well-formed inputs.
#![no_main]

use libfuzzer_sys::fuzz_target;
use uniap::service::Snapshot;

fuzz_target!(|data: &[u8]| {
    let Ok(text) = std::str::from_utf8(data) else { return };
    let Ok(snap) = Snapshot::parse(text) else { return };
    let emitted = snap.to_json().to_string();
    let reparsed = Snapshot::parse(&emitted).expect("emitted snapshot must re-parse");
    let merged = snap.merge(reparsed);
    assert_eq!(
        merged.to_json().to_string(),
        emitted,
        "self-merge must be idempotent on the emitted bytes"
    );
});
