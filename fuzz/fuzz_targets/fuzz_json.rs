//! Fuzz `util::json`: parsing must never panic (the parser is
//! depth-bounded by construction), and for any input that parses, the
//! compact emission is a fixed point of parse ∘ emit — the property the
//! snapshot checksums and golden-response tests stand on.
#![no_main]

use libfuzzer_sys::fuzz_target;
use uniap::util::json::Json;

fuzz_target!(|data: &[u8]| {
    let Ok(text) = std::str::from_utf8(data) else { return };
    let Ok(v) = Json::parse(text) else { return };
    let emitted = v.to_string();
    let reparsed = Json::parse(&emitted).expect("compact emission must re-parse");
    assert_eq!(reparsed.to_string(), emitted, "emission is a fixed point");
    let pretty = v.to_pretty();
    let from_pretty = Json::parse(&pretty).expect("pretty emission must re-parse");
    assert_eq!(
        from_pretty.to_string(),
        emitted,
        "pretty and compact forms agree on the canonical bytes"
    );
});
