//! Fuzz the NDJSON socket framing: `read_frame` over arbitrary bytes
//! must never panic, every yielded frame respects the byte cap with its
//! terminator stripped, and an oversized or non-UTF-8 stream surfaces as
//! a typed error, not unbounded buffering.
#![no_main]

use std::io::BufReader;

use libfuzzer_sys::fuzz_target;
use uniap::util::net::read_frame;

const CAP: usize = 128;

fuzz_target!(|data: &[u8]| {
    let mut reader = BufReader::new(data);
    // Bounded loop: each iteration consumes ≥ 1 input byte or exits, but
    // the explicit budget keeps a pathological reader from looping.
    for _ in 0..data.len() + 1 {
        match read_frame(&mut reader, CAP, &|| false) {
            Ok(Some(frame)) => {
                assert!(
                    frame.len() <= CAP + 2,
                    "frame exceeds cap: {} bytes",
                    frame.len()
                );
                assert!(!frame.contains('\n'), "terminator must be stripped");
            }
            Ok(None) => break,     // clean EOF
            Err(_) => break,       // typed error (oversized / not UTF-8)
        }
    }
});
